//! Model / serving / Kascade configuration.
//!
//! `ModelConfig` mirrors `python/compile/model.py::ModelConfig`; the AOT
//! manifest embeds the python-side values and [`ModelConfig::matches_manifest`]
//! guards against drift between the two layers.

/// Architecture hyperparameters of a SynthLM / PJRT model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f32,
    /// Whether rotary embeddings are applied to q/k.  The PJRT path always
    /// uses RoPE (it is baked into the HLO); the native eval preset may
    /// disable it to support very long contexts (DESIGN.md §2).
    pub rope: bool,
}

impl ModelConfig {
    /// GQA group size: query heads per KV head.
    pub fn group(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    /// The configuration the AOT artifacts were lowered for
    /// (python/compile/model.py defaults).
    pub fn pjrt_small() -> Self {
        Self {
            n_layers: 16,
            d_model: 256,
            n_q_heads: 8,
            n_kv_heads: 4,
            d_head: 32,
            d_ff: 1024,
            vocab: 4096,
            rope_theta: 10000.0,
            rope: true,
        }
    }

    /// Native-engine preset for long-context accuracy experiments.
    /// Same shape as `pjrt_small` but NoPE, so retrieval circuits stay
    /// exact out to 128k-token contexts.
    pub fn eval_base() -> Self {
        Self {
            rope: false,
            ..Self::pjrt_small()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_q_heads % self.n_kv_heads != 0 {
            return Err(format!(
                "n_q_heads ({}) must be a multiple of n_kv_heads ({})",
                self.n_q_heads, self.n_kv_heads
            ));
        }
        if self.d_head % 2 != 0 && self.rope {
            return Err("RoPE requires an even d_head".into());
        }
        if self.n_q_heads * self.d_head != self.d_model {
            // Not fatal (wo projects back), but our wiring assumes it.
            return Err(format!(
                "wiring assumes n_q_heads * d_head == d_model ({} * {} != {})",
                self.n_q_heads, self.d_head, self.d_model
            ));
        }
        Ok(())
    }
}

/// Storage precision of the paged KV cache.
///
/// `Int8` stores full quantization tiles (one tile = the cache's page
/// size, matching the block size) as int8 with a per-tile, per-head
/// affine `(scale, zero)` pair for K and for V; the partially-filled
/// tail tile stays f32 in a small staging buffer until it completes.
/// Tile Top-k *scoring* (Kascade anchors, pooled scores, OmniKV
/// filters) runs fused over the int8 rows without materializing f32
/// ([`crate::tensor::qk_dot_q8`]); only the value rows actually
/// attended (the selected Top-k, or everything on a dense fallback)
/// are dequantized.  See `docs/serving.md` § KV storage modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    Int8,
}

impl KvDtype {
    pub fn label(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        }
    }
}

/// The paper's Top-k rule (Sec. 4.1): `k = min(max(frac * L, min_k), L)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopKRule {
    pub frac: f32,
    pub min_k: usize,
}

impl Default for TopKRule {
    fn default() -> Self {
        Self { frac: 0.10, min_k: 128 }
    }
}

impl TopKRule {
    pub fn new(frac: f32, min_k: usize) -> Self {
        Self { frac, min_k }
    }

    /// k for a context of `len` tokens.
    pub fn k(&self, len: usize) -> usize {
        ((self.frac * len as f32) as usize).max(self.min_k).min(len)
    }
}

/// Serving-side knobs for the coordinator.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// KV-cache page size in tokens.
    pub block_size: usize,
    /// Total KV-cache blocks across the pool.
    pub num_blocks: usize,
    /// Max sequences admitted into the running batch.
    pub max_running: usize,
    /// Token budget per scheduler tick (prefill chunk + decodes).
    pub token_budget: usize,
    /// Prefill chunk size (tokens) for chunked prefill.
    pub prefill_chunk: usize,
    /// Waiting-queue capacity before admission control rejects.
    pub queue_cap: usize,
    /// Number of worker executors the router spreads sequences over.
    pub workers: usize,
    /// Automatic prefix caching: retain + share full KV blocks across
    /// sequences with equal prompt prefixes, skipping both the KV
    /// storage and the prefill compute for the shared blocks.  Off by
    /// default (opt-in; RAG / agentic workloads benefit most).
    pub enable_prefix_cache: bool,
    /// Max refcount-0 blocks retained in the prefix-cache pool before
    /// LRU eviction (only meaningful with `enable_prefix_cache`).
    pub prefix_cache_blocks: usize,
    /// Execute each tick's decodes as one step-batched forward pass on
    /// batch-capable backends (layer-major over the batch, amortizing
    /// weight reads).  Logits are bitwise-identical to the sequential
    /// path; disable only to measure the sequential baseline.
    pub batched_decode: bool,
    /// Storage precision for paged KV blocks ([`KvDtype`]).  `Int8`
    /// roughly quarters resident KV bytes (per-tile scales + the f32
    /// staging tail are the overhead) at a bounded output divergence;
    /// backends created for this config and the block manager's
    /// per-block mode bookkeeping both follow it.
    pub kv_dtype: KvDtype,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            block_size: 16,
            num_blocks: 4096,
            max_running: 64,
            token_budget: 2048,
            prefill_chunk: 512,
            queue_cap: 1024,
            workers: 1,
            enable_prefix_cache: false,
            prefix_cache_blocks: 1024,
            batched_decode: true,
            kv_dtype: KvDtype::F32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_rule_matches_paper() {
        let r = TopKRule::default();
        assert_eq!(r.k(512), 128); // floor dominates
        assert_eq!(r.k(1280), 128);
        assert_eq!(r.k(2048), 204); // 10%
        assert_eq!(r.k(100), 100); // capped at L
        assert_eq!(r.k(4096), 409);
    }

    #[test]
    fn presets_validate() {
        ModelConfig::pjrt_small().validate().unwrap();
        ModelConfig::eval_base().validate().unwrap();
    }

    #[test]
    fn group_size() {
        assert_eq!(ModelConfig::pjrt_small().group(), 2);
    }

    #[test]
    fn bad_config_rejected() {
        let mut c = ModelConfig::pjrt_small();
        c.n_kv_heads = 3;
        assert!(c.validate().is_err());
    }
}
