//! Model / serving / Kascade configuration.
//!
//! `ModelConfig` mirrors `python/compile/model.py::ModelConfig`; the AOT
//! manifest embeds the python-side values and [`ModelConfig::matches_manifest`]
//! guards against drift between the two layers.

/// Architecture hyperparameters of a SynthLM / PJRT model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f32,
    /// Whether rotary embeddings are applied to q/k.  The PJRT path always
    /// uses RoPE (it is baked into the HLO); the native eval preset may
    /// disable it to support very long contexts (DESIGN.md §2).
    pub rope: bool,
}

impl ModelConfig {
    /// GQA group size: query heads per KV head.
    pub fn group(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    /// The configuration the AOT artifacts were lowered for
    /// (python/compile/model.py defaults).
    pub fn pjrt_small() -> Self {
        Self {
            n_layers: 16,
            d_model: 256,
            n_q_heads: 8,
            n_kv_heads: 4,
            d_head: 32,
            d_ff: 1024,
            vocab: 4096,
            rope_theta: 10000.0,
            rope: true,
        }
    }

    /// Native-engine preset for long-context accuracy experiments.
    /// Same shape as `pjrt_small` but NoPE, so retrieval circuits stay
    /// exact out to 128k-token contexts.
    pub fn eval_base() -> Self {
        Self {
            rope: false,
            ..Self::pjrt_small()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_q_heads % self.n_kv_heads != 0 {
            return Err(format!(
                "n_q_heads ({}) must be a multiple of n_kv_heads ({})",
                self.n_q_heads, self.n_kv_heads
            ));
        }
        if self.d_head % 2 != 0 && self.rope {
            return Err("RoPE requires an even d_head".into());
        }
        if self.n_q_heads * self.d_head != self.d_model {
            // Not fatal (wo projects back), but our wiring assumes it.
            return Err(format!(
                "wiring assumes n_q_heads * d_head == d_model ({} * {} != {})",
                self.n_q_heads, self.d_head, self.d_model
            ));
        }
        Ok(())
    }
}

/// Storage precision of the paged KV cache.
///
/// The quantized/converted modes all share the same tile architecture
/// (one tile = the cache's page size, matching the block size): the
/// partially-filled tail tile stays f32 in a small staging buffer and is
/// converted **once** when the tile completes, so tile (= block)
/// boundaries are byte-stable across CoW/prefix forks.
///
/// * `F16` stores completed K/V tiles as IEEE binary16 with f32
///   accumulation in every kernel (software-converted via
///   [`crate::tensor::f32_to_f16`], so bytes are host-independent).
///   Per-element relative error ≤ 2^-11; no per-tile params.
/// * `Int8` stores int8 codes with a per-tile, per-head affine
///   `(scale, zero)` pair for K and for V.  Tile Top-k *scoring*
///   (Kascade anchors, pooled scores, OmniKV filters) runs fused over
///   the codes without materializing f32
///   ([`crate::tensor::qk_dot_q8`]); only the value rows actually
///   attended (the selected Top-k, or everything on a dense fallback)
///   are dequantized.  Round-trip error ≤ (max-min)/508 per tile-head.
/// * `Int4` packs two affine codes per byte ([`crate::tensor::quantize_q4`]
///   layout, promoted from the warm-tier diagnostic to a first-class
///   kernel-readable mode): same per-tile-per-head `(scale, zero)`
///   params as int8, fused scoring over the packed nibbles
///   ([`crate::tensor::qk_dot_q4`]), round-trip error ≤ (max-min)/28.
///   Requires an even head dimension.
///
/// See `docs/serving.md` § KV storage modes for the full matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    F16,
    Int8,
    Int4,
}

impl KvDtype {
    pub fn label(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
            KvDtype::Int4 => "int4",
        }
    }

    /// Parse a CLI/config label (the inverse of [`Self::label`]).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(KvDtype::F32),
            "f16" => Some(KvDtype::F16),
            "int8" => Some(KvDtype::Int8),
            "int4" => Some(KvDtype::Int4),
            _ => None,
        }
    }

    /// True for modes that store completed tiles in a non-f32 plane
    /// (and therefore keep the f32 staging tail).
    pub fn is_compressed(&self) -> bool {
        !matches!(self, KvDtype::F32)
    }
}

/// Token-selection rule for decode, applied identically by the
/// sequential and step-batched execution paths (both retire tokens
/// through `Sequence::apply_decoded_logits`) and by the standalone
/// [`crate::model::Model::sample_decode`] loop.
///
/// Fully deterministic: `Seeded` draws from a counter-based RNG keyed by
/// `(seed, response position)`, so replays — preemption recompute,
/// batched vs. sequential execution, a re-run of the same request —
/// select identical tokens.  Ties in the candidate ordering break toward
/// the lower token index.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SamplingParams {
    /// Argmax (the default; bitwise-deterministic).
    #[default]
    Greedy,
    /// Softmax sampling at `temperature`, truncated to the `top_k`
    /// highest-logit tokens (`0` disables) and then to the smallest
    /// nucleus with probability mass >= `top_p` (`1.0` disables).
    Seeded { temperature: f32, top_k: usize, top_p: f32, seed: u64 },
}

impl SamplingParams {
    /// Seeded sampling with neutral knobs (temperature 1, no truncation).
    pub fn seeded(seed: u64) -> Self {
        SamplingParams::Seeded { temperature: 1.0, top_k: 0, top_p: 1.0, seed }
    }

    pub fn temperature(self, t: f32) -> Self {
        match self {
            SamplingParams::Seeded { top_k, top_p, seed, .. } => {
                SamplingParams::Seeded { temperature: t, top_k, top_p, seed }
            }
            g => g,
        }
    }

    pub fn top_k(self, k: usize) -> Self {
        match self {
            SamplingParams::Seeded { temperature, top_p, seed, .. } => {
                SamplingParams::Seeded { temperature, top_k: k, top_p, seed }
            }
            g => g,
        }
    }

    pub fn top_p(self, p: f32) -> Self {
        match self {
            SamplingParams::Seeded { temperature, top_k, seed, .. } => {
                SamplingParams::Seeded { temperature, top_k, top_p: p, seed }
            }
            g => g,
        }
    }

    /// Counter-based uniform draw in [0, 1): splitmix-style finalizer of
    /// `(seed, pos)`, so the draw for a response position is a pure
    /// function of the request seed — independent of execution order.
    fn unit_uniform(seed: u64, pos: u64) -> f64 {
        let z = crate::tensor::splitmix64(
            (seed ^ pos.wrapping_mul(0x9E37_79B9_7F4A_7C15)).wrapping_add(0xD1B5_4A32_D192_ED03),
        );
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Select the token for response position `pos` from `logits`.
    pub fn sample(&self, logits: &[f32], pos: usize) -> u32 {
        match *self {
            SamplingParams::Greedy => crate::tensor::argmax(logits) as u32,
            SamplingParams::Seeded { temperature, top_k, top_p, seed } => {
                if !(temperature > 0.0) {
                    // the T -> 0 limit of softmax sampling is argmax
                    return crate::tensor::argmax(logits) as u32;
                }
                let t = temperature as f64;
                if top_k == 0 && top_p >= 1.0 {
                    // no truncation: one O(V) pass over the logits in
                    // index order (no sort, no index buffer) — the hot
                    // decode path for plain temperature sampling
                    let m = logits.iter().fold(f64::NEG_INFINITY, |a, &x| a.max(x as f64));
                    let mut sum = 0.0f64;
                    for &x in logits {
                        sum += ((x as f64 - m) / t).exp();
                    }
                    let mut u = Self::unit_uniform(seed, pos as u64) * sum;
                    for (i, &x) in logits.iter().enumerate() {
                        u -= ((x as f64 - m) / t).exp();
                        if u <= 0.0 {
                            return i as u32;
                        }
                    }
                    return logits.len().saturating_sub(1) as u32;
                }
                // candidates ordered by logit desc, index asc on ties
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| {
                    logits[b]
                        .partial_cmp(&logits[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                if top_k > 0 && top_k < idx.len() {
                    idx.truncate(top_k);
                }
                // softmax over the candidates in f64 (stable + identical
                // regardless of how the f32 logits were produced)
                let m = logits[idx[0]] as f64;
                let mut probs: Vec<f64> =
                    idx.iter().map(|&i| ((logits[i] as f64 - m) / t).exp()).collect();
                let sum: f64 = probs.iter().sum();
                for p in &mut probs {
                    *p /= sum;
                }
                // nucleus: smallest prefix of the sorted candidates whose
                // mass reaches top_p (the crossing token is included)
                let mut keep = probs.len();
                if top_p < 1.0 {
                    let mut acc = 0.0;
                    for (i, p) in probs.iter().enumerate() {
                        acc += p;
                        if acc >= top_p as f64 {
                            keep = i + 1;
                            break;
                        }
                    }
                }
                let mass: f64 = probs[..keep].iter().sum();
                let mut u = Self::unit_uniform(seed, pos as u64) * mass;
                for (i, p) in probs[..keep].iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return idx[i] as u32;
                    }
                }
                idx[keep - 1] as u32
            }
        }
    }
}

/// The paper's Top-k rule (Sec. 4.1): `k = min(max(frac * L, min_k), L)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopKRule {
    pub frac: f32,
    pub min_k: usize,
}

impl Default for TopKRule {
    fn default() -> Self {
        Self { frac: 0.10, min_k: 128 }
    }
}

impl TopKRule {
    pub fn new(frac: f32, min_k: usize) -> Self {
        Self { frac, min_k }
    }

    /// k for a context of `len` tokens.
    pub fn k(&self, len: usize) -> usize {
        ((self.frac * len as f32) as usize).max(self.min_k).min(len)
    }
}

/// Serving-side knobs for the coordinator.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// KV-cache page size in tokens.
    pub block_size: usize,
    /// Total KV-cache blocks across the pool.
    pub num_blocks: usize,
    /// Max sequences admitted into the running batch.
    pub max_running: usize,
    /// Token budget per scheduler tick (prefill chunk + decodes).
    pub token_budget: usize,
    /// Prefill chunk size (tokens) for chunked prefill.
    pub prefill_chunk: usize,
    /// Waiting-queue capacity before admission control rejects.
    pub queue_cap: usize,
    /// Number of worker executors the router spreads sequences over.
    pub workers: usize,
    /// Automatic prefix caching: retain + share full KV blocks across
    /// sequences with equal prompt prefixes, skipping both the KV
    /// storage and the prefill compute for the shared blocks.  Off by
    /// default (opt-in; RAG / agentic workloads benefit most).
    pub enable_prefix_cache: bool,
    /// Max refcount-0 blocks retained in the prefix-cache pool before
    /// LRU eviction (only meaningful with `enable_prefix_cache`).
    pub prefix_cache_blocks: usize,
    /// Execute each tick's decodes as one step-batched forward pass on
    /// batch-capable backends (layer-major over the batch, amortizing
    /// weight reads).  Logits are bitwise-identical to the sequential
    /// path; disable only to measure the sequential baseline.
    pub batched_decode: bool,
    /// Storage precision for paged KV blocks ([`KvDtype`]).  `F16`
    /// halves and `Int8` roughly quarters resident KV bytes (per-tile
    /// scales + the f32 staging tail are the overhead); `Int4` cuts
    /// them ~8x at a correspondingly larger bounded divergence.
    /// Backends created for this config and the block manager's
    /// per-block mode bookkeeping both follow it.
    pub kv_dtype: KvDtype,
    /// Hard cap on prompt length accepted at submit
    /// (`SubmitError::PromptTooLong`).  `None` bounds prompts only by
    /// what the block pool can physically hold (a prompt that could
    /// never decode a single token is rejected up front instead of
    /// livelocking admission).
    pub max_prompt_tokens: Option<usize>,
    /// Worker threads for the engine's parallel decode tick.  `> 1`
    /// spawns a persistent [`crate::pool::WorkerPool`] per engine and
    /// shards each tick's batched decode across sequences (policy phase)
    /// and `(sequence, KV head)` work items (attention phase).  Output
    /// streams are **bitwise identical** to `num_threads = 1` — every
    /// work item is self-contained and reductions fold in fixed order
    /// (fuzz-tested in `tests/parallel_tick.rs`).  Default 1 (serial,
    /// and the only mode with the zero-allocation-per-token guarantee).
    pub num_threads: usize,
    /// Decode-tick protection for chunked-prefill interleaving: when any
    /// sequence is decoding, cap the total prefill tokens a single tick
    /// may schedule at this value (the cap also applies to the prefill
    /// work of sequences admitted in that same tick).  This bounds tick
    /// wall time — and with it TPOT jitter — while a huge (e.g. 128k+
    /// token) prefill is in flight: the prefill proceeds in small slices
    /// instead of consuming the whole `token_budget` between decode
    /// steps.  `None` (the default) keeps the legacy behaviour where a
    /// running prefill may take up to `prefill_chunk`/`token_budget`
    /// tokens per tick regardless of live decoders.
    pub decode_guard_prefill_tokens: Option<usize>,
    /// Tiered KV storage (`docs/kv-tiers.md`): run the reuse layers of
    /// sparsity-hinting policies (Kascade) under a bounded hot-tile
    /// arena, demoting cold tiles through an int4 warm shadow to a
    /// file-backed spill store and promoting the tiles the anchor
    /// layers' Top-k selections hint at.  Requires `kv_dtype: Int8`;
    /// layers that scan every position (anchors, dense baselines) stay
    /// fully resident, so enabling this under a non-hinting policy is a
    /// no-op.  Off by default.
    pub kv_tiers: bool,
    /// Hot-tile budget per sequence per tiered layer (completed
    /// quantization tiles of `block_size` tokens each).  Demand
    /// promotion may transiently overshoot this (correctness first);
    /// tick-boundary maintenance trims back.  Only meaningful with
    /// `kv_tiers`.
    pub hot_tile_budget: usize,
    /// Per-tenant fair-share admission, layered on the priority queue.
    /// When enabled, admission picks — among the highest-priority
    /// non-recovering waiters — the request whose tenant has consumed
    /// the fewest admitted prompt tokens, so a tenant flooding the queue
    /// (10:1 skew and beyond) cannot starve the others.  Priority and
    /// preemption-recovery ordering still dominate: a recovering victim
    /// keeps its head-of-queue slot and a strictly higher priority wins
    /// regardless of tenant debt.  Off by default (pure FCFS within
    /// priority, exactly the pre-fair-share behaviour).
    pub fair_share: bool,
    /// Time-to-first-token SLO in wall-clock milliseconds — the p95
    /// target the SLO-gated traffic scenarios (and any deadline-aware
    /// operator tooling) hold the deployment to.  Promoted from the
    /// former hard-coded bench constants so a tenant class can carry its
    /// own target.  Informational to the scheduler itself: admission
    /// does not shed on it (yet), harnesses assert on it.
    pub ttft_slo_ms: f64,
    /// Time-per-output-token SLO (p95, wall-clock milliseconds); see
    /// [`Self::ttft_slo_ms`].
    pub tpot_slo_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            block_size: 16,
            num_blocks: 4096,
            max_running: 64,
            token_budget: 2048,
            prefill_chunk: 512,
            queue_cap: 1024,
            workers: 1,
            enable_prefix_cache: false,
            prefix_cache_blocks: 1024,
            batched_decode: true,
            kv_dtype: KvDtype::F32,
            max_prompt_tokens: None,
            num_threads: 1,
            decode_guard_prefill_tokens: None,
            kv_tiers: false,
            hot_tile_budget: 256,
            fair_share: false,
            ttft_slo_ms: 500.0,
            tpot_slo_ms: 20.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_rule_matches_paper() {
        let r = TopKRule::default();
        assert_eq!(r.k(512), 128); // floor dominates
        assert_eq!(r.k(1280), 128);
        assert_eq!(r.k(2048), 204); // 10%
        assert_eq!(r.k(100), 100); // capped at L
        assert_eq!(r.k(4096), 409);
    }

    #[test]
    fn presets_validate() {
        ModelConfig::pjrt_small().validate().unwrap();
        ModelConfig::eval_base().validate().unwrap();
    }

    #[test]
    fn group_size() {
        assert_eq!(ModelConfig::pjrt_small().group(), 2);
    }

    #[test]
    fn bad_config_rejected() {
        let mut c = ModelConfig::pjrt_small();
        c.n_kv_heads = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1, 2.5, -1.0, 2.4];
        assert_eq!(SamplingParams::Greedy.sample(&logits, 0), 1);
        assert_eq!(SamplingParams::default().sample(&logits, 7), 1);
    }

    #[test]
    fn seeded_sampling_is_deterministic_per_seed_and_pos() {
        let logits: Vec<f32> = (0..64).map(|i| ((i * 37 % 64) as f32) * 0.07).collect();
        let s = SamplingParams::seeded(42).temperature(1.2).top_k(16).top_p(0.9);
        for pos in 0..32 {
            assert_eq!(s.sample(&logits, pos), s.sample(&logits, pos));
        }
        // different positions (and seeds) must actually vary the draw
        let picks: std::collections::HashSet<u32> =
            (0..32).map(|p| s.sample(&logits, p)).collect();
        assert!(picks.len() > 1, "seeded sampling never varied across positions");
        let other = SamplingParams::seeded(43).temperature(1.2).top_k(16).top_p(0.9);
        let a: Vec<u32> = (0..32).map(|p| s.sample(&logits, p)).collect();
        let b: Vec<u32> = (0..32).map(|p| other.sample(&logits, p)).collect();
        assert_ne!(a, b, "different seeds produced identical 32-token streams");
    }

    #[test]
    fn sampling_truncations_collapse_to_argmax() {
        let logits = vec![0.3, 4.0, 0.2, 3.9, -2.0];
        // top_k = 1 and a tiny nucleus both leave only the max token
        let k1 = SamplingParams::seeded(9).top_k(1);
        let p_small = SamplingParams::seeded(9).top_p(1e-6);
        let cold = SamplingParams::seeded(9).temperature(0.0);
        for pos in 0..16 {
            assert_eq!(k1.sample(&logits, pos), 1);
            assert_eq!(p_small.sample(&logits, pos), 1);
            assert_eq!(cold.sample(&logits, pos), 1);
        }
    }

    #[test]
    fn nucleus_respects_mass_bound() {
        // 0.7 mass on token 0: top_p(0.6) must always pick it
        let logits = vec![2.0, 0.0, -1.0, -1.0];
        let s = SamplingParams::seeded(5).top_p(0.6);
        for pos in 0..32 {
            assert_eq!(s.sample(&logits, pos), 0);
        }
    }
}
