//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Interchange is HLO *text* (see DESIGN.md / aot.py): jax >= 0.5 emits
//! protos with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.  Every artifact returns a tuple (lowered with
//! `return_tuple=True`), unpacked here with `Literal::to_tuple`.

pub mod manifest;
pub mod pjrt_model;

pub use manifest::{ArtifactMeta, Manifest};
pub use pjrt_model::{PjrtModel, PjrtSeqState};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Lazy-compiling executor over an artifact directory.
pub struct Runtime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { dir: dir.to_path_buf(), manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    /// Compile (or fetch cached) executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact; returns the flattened tuple elements.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// f32 literal with shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != len {}", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// i32 literal with shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != len {}", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Copy a literal's f32 payload out.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
}

pub fn lit_to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
}
