//! AOT manifest parsing (artifacts/manifest.json) and shape-bucket logic.

use crate::config::ModelConfig;
use crate::jsonutil::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Context bucket (decode) / prompt bucket (prefill), if applicable.
    pub l: Option<usize>,
    pub t: Option<usize>,
    /// Top-k size baked into the artifact.
    pub k: Option<usize>,
    pub tile: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub decode_l: Vec<usize>,
    pub prefill_t: Vec<usize>,
    pub tile: usize,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

fn specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s.req("shape")?.usize_vec()?,
                dtype: s.req("dtype")?.as_str().unwrap_or("float32").to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let c = j.req("config")?;
        let config = ModelConfig {
            n_layers: c.req("n_layers")?.as_usize().unwrap(),
            d_model: c.req("d_model")?.as_usize().unwrap(),
            n_q_heads: c.req("n_q_heads")?.as_usize().unwrap(),
            n_kv_heads: c.req("n_kv_heads")?.as_usize().unwrap(),
            d_head: c.req("d_head")?.as_usize().unwrap(),
            d_ff: c.req("d_ff")?.as_usize().unwrap(),
            vocab: c.req("vocab")?.as_usize().unwrap(),
            rope_theta: c.req("rope_theta")?.as_f64().unwrap() as f32,
            rope: true,
        };
        let b = j.req("buckets")?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().unwrap() {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: a.req("file")?.as_str().unwrap().to_string(),
                    kind: a.req("kind")?.as_str().unwrap().to_string(),
                    inputs: specs(a.req("inputs")?)?,
                    outputs: specs(a.req("outputs")?)?,
                    l: a.get("l").and_then(|v| v.as_usize()),
                    t: a.get("t").and_then(|v| v.as_usize()),
                    k: a.get("k").and_then(|v| v.as_usize()),
                    tile: a.get("tile").and_then(|v| v.as_usize()),
                },
            );
        }
        Ok(Self {
            config,
            decode_l: b.req("decode_l")?.usize_vec()?,
            prefill_t: b.req("prefill_t")?.usize_vec()?,
            tile: b.req("tile")?.as_usize().unwrap_or(128),
            artifacts,
        })
    }

    /// Smallest decode KV bucket that can hold `len` tokens.
    pub fn decode_bucket(&self, len: usize) -> Option<usize> {
        self.decode_l.iter().copied().find(|&b| b >= len)
    }

    /// Smallest prefill bucket that can hold a `t`-token prompt.
    pub fn prefill_bucket(&self, t: usize) -> Option<usize> {
        self.prefill_t.iter().copied().find(|&b| b >= t)
    }

    /// Baked Top-k size of a decode bucket.
    pub fn decode_k(&self, bucket: usize) -> Option<usize> {
        self.artifacts
            .get(&format!("attn_reuse_decode_l{bucket}"))
            .and_then(|a| a.k)
    }

    pub fn prefill_k(&self, bucket: usize) -> Option<usize> {
        self.artifacts
            .get(&format!("attn_reuse_prefill_t{bucket}"))
            .and_then(|a| a.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"n_layers": 16, "d_model": 256, "n_q_heads": 8,
                 "n_kv_heads": 4, "d_head": 32, "d_ff": 1024,
                 "vocab": 4096, "rope_theta": 10000.0},
      "buckets": {"decode_l": [512, 1024, 2048], "prefill_t": [128, 512], "tile": 128},
      "k_rule": {"frac": 0.1, "min": 128},
      "artifacts": {
        "attn_reuse_decode_l512": {
          "file": "attn_reuse_decode_l512.hlo.txt",
          "kind": "attn_reuse_decode", "l": 512, "k": 128,
          "inputs": [{"shape": [8, 32], "dtype": "float32"},
                     {"shape": [4, 512, 32], "dtype": "float32"},
                     {"shape": [4, 512, 32], "dtype": "float32"},
                     {"shape": [4, 128], "dtype": "int32"}],
          "outputs": [{"shape": [8, 32], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.n_layers, 16);
        assert_eq!(m.config.n_kv_heads, 4);
        assert_eq!(m.decode_l, vec![512, 1024, 2048]);
        let a = &m.artifacts["attn_reuse_decode_l512"];
        assert_eq!(a.k, Some(128));
        assert_eq!(a.inputs[3].dtype, "int32");
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.decode_bucket(1), Some(512));
        assert_eq!(m.decode_bucket(512), Some(512));
        assert_eq!(m.decode_bucket(513), Some(1024));
        assert_eq!(m.decode_bucket(2049), None);
        assert_eq!(m.prefill_bucket(100), Some(128));
        assert_eq!(m.prefill_bucket(400), Some(512));
        assert_eq!(m.decode_k(512), Some(128));
    }

    #[test]
    fn real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !p.exists() {
            return; // `make artifacts` not run yet
        }
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.config, ModelConfig::pjrt_small());
        for (name, a) in &m.artifacts {
            assert!(!a.inputs.is_empty() || a.kind == "const", "{name}");
        }
        // every bucket has all four decode attention variants
        for l in &m.decode_l {
            for kind in ["dense", "anchor", "anchor0", "reuse"] {
                assert!(m.artifacts.contains_key(&format!("attn_{kind}_decode_l{l}")));
            }
        }
    }
}
