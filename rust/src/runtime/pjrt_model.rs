//! PJRT-backed model execution: drives the layer-granular HLO artifacts
//! with the Rust coordinator owning the per-layer Kascade schedule.
//!
//! Weights are uploaded to the PJRT device **once** at construction
//! (`buffer_from_host_buffer`) and every op executes via `execute_b`, so
//! the per-step host->device traffic is only the activations, KV slices
//! and Top-k indices (see EXPERIMENTS.md §Perf for the literal-vs-buffer
//! comparison that motivated this).

use super::{lit_to_f32, lit_to_i32, Runtime};
use crate::config::ModelConfig;
use crate::kascade::{KascadePlan, LayerRole};
use crate::model::Weights;
use anyhow::{Context, Result};
use xla::PjRtBuffer;

struct LayerBufs {
    ln1: PjRtBuffer,
    wq: PjRtBuffer,
    wk: PjRtBuffer,
    wv: PjRtBuffer,
    wo: PjRtBuffer,
    ln2: PjRtBuffer,
    w1: PjRtBuffer,
    w3: PjRtBuffer,
    w2: PjRtBuffer,
}

pub struct PjrtModel {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    w_e: PjRtBuffer,
    lnf: PjRtBuffer,
    w_u: PjRtBuffer,
    layers: Vec<LayerBufs>,
}

/// Host-side per-sequence state for the PJRT path.
pub struct PjrtSeqState {
    pub len: usize,
    pub cap: usize,
    /// per layer, `[n_kv * cap * d]` row-major (head-major, then position)
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// freshest Top-k indices per anchor layer, flattened `[n_kv * kk]`
    pub idx: Vec<Option<(Vec<i32>, usize)>>,
}

impl PjrtModel {
    pub fn new(rt: Runtime, weights: &Weights) -> Result<Self> {
        let cfg = rt.manifest.config;
        let up = |data: &[f32], dims: &[usize]| -> Result<PjRtBuffer> {
            rt.client()
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
        };
        let (dm, dh, f, v) = (cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for lw in &weights.layers {
            layers.push(LayerBufs {
                ln1: up(&lw.ln1, &[dm])?,
                wq: up(&lw.wq, &[dm, cfg.n_q_heads * dh])?,
                wk: up(&lw.wk, &[dm, cfg.n_kv_heads * dh])?,
                wv: up(&lw.wv, &[dm, cfg.n_kv_heads * dh])?,
                wo: up(&lw.wo, &[cfg.n_q_heads * dh, dm])?,
                ln2: up(&lw.ln2, &[dm])?,
                w1: up(&lw.w1, &[dm, f])?,
                w3: up(&lw.w3, &[dm, f])?,
                w2: up(&lw.w2, &[f, dm])?,
            });
        }
        Ok(Self {
            w_e: up(&weights.w_e, &[v, dm])?,
            lnf: up(&weights.lnf, &[dm])?,
            w_u: up(&weights.w_u, &[dm, v])?,
            layers,
            cfg,
            rt,
        })
    }

    pub fn new_state(&self) -> PjrtSeqState {
        let cap = *self.rt.manifest.decode_l.last().unwrap();
        let per = self.cfg.n_kv_heads * cap * self.cfg.d_head;
        PjrtSeqState {
            len: 0,
            cap,
            k: (0..self.cfg.n_layers).map(|_| vec![0.0; per]).collect(),
            v: (0..self.cfg.n_layers).map(|_| vec![0.0; per]).collect(),
            idx: vec![None; self.cfg.n_layers],
        }
    }

    fn upf(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.rt
            .client()
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
    }

    fn upi(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.rt
            .client()
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
    }

    fn run(&self, name: &str, inputs: &[&PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self.rt.executable(name)?;
        let out = exe
            .execute_b(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// KV-cache slices for `bucket`, per kv head, from the host cache.
    fn kv_bucket(&self, st: &PjrtSeqState, layer: usize, bucket: usize) -> (Vec<f32>, Vec<f32>) {
        let (n_kv, d) = (self.cfg.n_kv_heads, self.cfg.d_head);
        let mut k = vec![0.0f32; n_kv * bucket * d];
        let mut v = vec![0.0f32; n_kv * bucket * d];
        for h in 0..n_kv {
            let src = h * st.cap * d;
            let dst = h * bucket * d;
            let n = st.len.min(bucket) * d;
            k[dst..dst + n].copy_from_slice(&st.k[layer][src..src + n]);
            v[dst..dst + n].copy_from_slice(&st.v[layer][src..src + n]);
        }
        (k, v)
    }

    /// Append `count` positions from `[n_kv, src_t, d]`-shaped projections.
    fn push_kv(
        &self,
        st: &mut PjrtSeqState,
        layer: usize,
        k_new: &[f32],
        v_new: &[f32],
        src_t: usize,
        count: usize,
    ) {
        let (n_kv, d) = (self.cfg.n_kv_heads, self.cfg.d_head);
        for h in 0..n_kv {
            for i in 0..count {
                let pos = st.len + i;
                let dst = (h * st.cap + pos) * d;
                let src = (h * src_t + i) * d;
                st.k[layer][dst..dst + d].copy_from_slice(&k_new[src..src + d]);
                st.v[layer][dst..dst + d].copy_from_slice(&v_new[src..src + d]);
            }
        }
    }

    /// Remap + pad anchor indices for a reuse layer at Top-k size `kk`.
    fn remap_idx(&self, idx: &(Vec<i32>, usize), head_map: &[usize], kk: usize) -> Vec<i32> {
        let (flat, src_kk) = idx;
        let n_kv = self.cfg.n_kv_heads;
        let mut out = vec![-1i32; n_kv * kk];
        for (hb, &ha) in head_map.iter().enumerate() {
            let n = (*src_kk).min(kk);
            out[hb * kk..hb * kk + n].copy_from_slice(&flat[ha * src_kk..ha * src_kk + n]);
        }
        out
    }

    /// One decode step.  `plan = None` runs dense attention in every layer.
    /// Returns the next-token logits.
    pub fn decode_step(
        &self,
        token: u32,
        st: &mut PjrtSeqState,
        plan: Option<&KascadePlan>,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let pos = st.len;
        let bucket = self
            .rt
            .manifest
            .decode_bucket(pos + 1)
            .with_context(|| format!("context {} exceeds largest decode bucket", pos + 1))?;
        let kk = self.rt.manifest.decode_k(bucket).unwrap();
        let len_buf = self.upi(&[(pos + 1) as i32], &[1])?;

        // embed
        let tok_buf = self.upi(&[token as i32], &[1])?;
        let x_lit = &self.run("embed_decode", &[&tok_buf, &self.w_e])?[0];
        let mut x = lit_to_f32(x_lit)?; // [1, D]

        let pos_buf = self.upi(&[pos as i32], &[1])?;
        for layer in 0..cfg.n_layers {
            let lb = &self.layers[layer];
            let x_buf = self.upf(&x, &[1, cfg.d_model])?;
            let qkv = self.run(
                "qkv_decode",
                &[&x_buf, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &pos_buf],
            )?;
            let q = lit_to_f32(&qkv[0])?; // [n_q, 1, d] == [n_q, d]
            let k_new = lit_to_f32(&qkv[1])?;
            let v_new = lit_to_f32(&qkv[2])?;
            self.push_kv(st, layer, &k_new, &v_new, 1, 1);
            st.len += 1; // visible to this layer's attention
            let (kc, vc) = self.kv_bucket(st, layer, bucket);
            st.len -= 1;

            let q_buf = self.upf(&q, &[cfg.n_q_heads, cfg.d_head])?;
            let k_buf = self.upf(&kc, &[cfg.n_kv_heads, bucket, cfg.d_head])?;
            let v_buf = self.upf(&vc, &[cfg.n_kv_heads, bucket, cfg.d_head])?;

            let role = plan.map(|p| p.role(layer));
            let attn: Vec<f32> = match role {
                None => {
                    let out = self.run(
                        &format!("attn_dense_decode_l{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?;
                    lit_to_f32(&out[0])?
                }
                Some(LayerRole::Anchor0) => {
                    let out = self.run(
                        &format!("attn_anchor0_decode_l{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?;
                    st.idx[layer] = Some((lit_to_i32(&out[1])?, kk));
                    lit_to_f32(&out[0])?
                }
                Some(LayerRole::Anchor) => {
                    let out = self.run(
                        &format!("attn_anchor_decode_l{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?;
                    st.idx[layer] = Some((lit_to_i32(&out[1])?, kk));
                    lit_to_f32(&out[0])?
                }
                Some(LayerRole::Reuse { anchor }) => match &st.idx[anchor] {
                    Some(aidx) => {
                        let plan = plan.unwrap();
                        let idx = self.remap_idx(aidx, &plan.head_map[layer], kk);
                        let idx_buf = self.upi(&idx, &[cfg.n_kv_heads, kk])?;
                        let out = self.run(
                            &format!("attn_reuse_decode_l{bucket}"),
                            &[&q_buf, &k_buf, &v_buf, &idx_buf],
                        )?;
                        lit_to_f32(&out[0])?
                    }
                    None => {
                        let out = self.run(
                            &format!("attn_dense_decode_l{bucket}"),
                            &[&q_buf, &k_buf, &v_buf, &len_buf],
                        )?;
                        lit_to_f32(&out[0])?
                    }
                },
            };

            // post: residual + MLP
            let attn_buf = self.upf(&attn, &[cfg.n_q_heads, 1, cfg.d_head])?;
            let x_buf = self.upf(&x, &[1, cfg.d_model])?;
            let out = self.run(
                "post_decode",
                &[&x_buf, &attn_buf, &lb.wo, &lb.ln2, &lb.w1, &lb.w3, &lb.w2],
            )?;
            x = lit_to_f32(&out[0])?;
        }
        st.len += 1;

        let x_buf = self.upf(&x, &[1, cfg.d_model])?;
        let out = self.run("logits_decode", &[&x_buf, &self.lnf, &self.w_u])?;
        lit_to_f32(&out[0])
    }

    /// Full-prompt prefill (prompt must fit the largest prefill bucket).
    /// Returns the last token's logits.
    pub fn prefill(
        &self,
        tokens: &[u32],
        st: &mut PjrtSeqState,
        plan: Option<&KascadePlan>,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(st.len == 0, "PJRT prefill must start an empty sequence");
        let cfg = &self.cfg;
        let t_real = tokens.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(t_real)
            .with_context(|| format!("prompt of {t_real} exceeds largest prefill bucket"))?;
        let kk = self.rt.manifest.prefill_k(bucket).unwrap();
        let nt = bucket / self.rt.manifest.tile;

        let mut toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0); // PAD
        let pos: Vec<i32> = (0..bucket as i32).collect();
        let len_buf = self.upi(&[t_real as i32], &[1])?;
        let tok_buf = self.upi(&toks, &[bucket])?;
        let pos_buf = self.upi(&pos, &[bucket])?;

        let x_lit = &self.run(&format!("embed_prefill_t{bucket}"), &[&tok_buf, &self.w_e])?[0];
        let mut x = lit_to_f32(x_lit)?; // [T, D]

        // per-anchor prefill indices for reuse within this prefill
        let mut pidx: Vec<Option<Vec<i32>>> = vec![None; cfg.n_layers];
        for layer in 0..cfg.n_layers {
            let lb = &self.layers[layer];
            let x_buf = self.upf(&x, &[bucket, cfg.d_model])?;
            let qkv = self.run(
                &format!("qkv_prefill_t{bucket}"),
                &[&x_buf, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &pos_buf],
            )?;
            let q = lit_to_f32(&qkv[0])?; // [n_q, T, d]
            let k_new = lit_to_f32(&qkv[1])?; // [n_kv, T, d]
            let v_new = lit_to_f32(&qkv[2])?;
            self.push_kv(st, layer, &k_new, &v_new, bucket, t_real.min(st.cap - st.len));

            let q_buf = self.upf(&q, &[cfg.n_q_heads, bucket, cfg.d_head])?;
            let k_buf = self.upf(&k_new, &[cfg.n_kv_heads, bucket, cfg.d_head])?;
            let v_buf = self.upf(&v_new, &[cfg.n_kv_heads, bucket, cfg.d_head])?;

            let role = plan.map(|p| p.role(layer));
            let attn: Vec<f32> = match role {
                None => lit_to_f32(
                    &self.run(
                        &format!("attn_dense_prefill_t{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?[0],
                )?,
                Some(LayerRole::Anchor0) => {
                    let out = self.run(
                        &format!("attn_anchor0_prefill_t{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?;
                    pidx[layer] = Some(lit_to_i32(&out[1])?);
                    lit_to_f32(&out[0])?
                }
                Some(LayerRole::Anchor) => {
                    let out = self.run(
                        &format!("attn_anchor_prefill_t{bucket}"),
                        &[&q_buf, &k_buf, &v_buf, &len_buf],
                    )?;
                    pidx[layer] = Some(lit_to_i32(&out[1])?);
                    lit_to_f32(&out[0])?
                }
                Some(LayerRole::Reuse { anchor }) => match &pidx[anchor] {
                    Some(aidx) => {
                        let plan = plan.unwrap();
                        // remap per tile: aidx is [n_kv, nt, kk]
                        let mut idx = vec![-1i32; cfg.n_kv_heads * nt * kk];
                        for (hb, &ha) in plan.head_map[layer].iter().enumerate() {
                            let n = nt * kk;
                            idx[hb * n..(hb + 1) * n]
                                .copy_from_slice(&aidx[ha * n..(ha + 1) * n]);
                        }
                        let idx_buf = self.upi(&idx, &[cfg.n_kv_heads, nt, kk])?;
                        lit_to_f32(
                            &self.run(
                                &format!("attn_reuse_prefill_t{bucket}"),
                                &[&q_buf, &k_buf, &v_buf, &idx_buf],
                            )?[0],
                        )?
                    }
                    None => lit_to_f32(
                        &self.run(
                            &format!("attn_dense_prefill_t{bucket}"),
                            &[&q_buf, &k_buf, &v_buf, &len_buf],
                        )?[0],
                    )?,
                },
            };

            let attn_buf = self.upf(&attn, &[cfg.n_q_heads, bucket, cfg.d_head])?;
            let x_buf = self.upf(&x, &[bucket, cfg.d_model])?;
            let out = self.run(
                &format!("post_prefill_t{bucket}"),
                &[&x_buf, &attn_buf, &lb.wo, &lb.ln2, &lb.w1, &lb.w3, &lb.w2],
            )?;
            x = lit_to_f32(&out[0])?;
        }
        st.len += t_real;

        let last = &x[(t_real - 1) * cfg.d_model..t_real * cfg.d_model];
        let x_buf = self.upf(last, &[1, cfg.d_model])?;
        let out = self.run("logits_decode", &[&x_buf, &self.lnf, &self.w_u])?;
        lit_to_f32(&out[0])
    }
}
