//! Minimal JSON substrate (no serde available offline): a value type,
//! a recursive-descent parser, and a writer.  Covers the subset the repo
//! needs — objects, arrays, strings, numbers, bools, null — with proper
//! string escaping and useful error positions.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn usize_arr(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // --- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (for required fields).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    /// Walk a dotted path (`"decode.tokens_per_s"`) through nested
    /// objects.  The shared lookup helper for every JSON consumer in the
    /// repo (bench gate, trajectory records, the analyzer's API surface)
    /// — one implementation, one set of edge cases.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Read and parse a JSON file, wrapping both I/O and parse errors
    /// with the offending path so callers can report one coherent error.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    // --- writer --------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // --- parser ------------------------------------------------------

    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            anyhow::bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\n' | b'\t' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => anyhow::bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("kascade")),
            ("anchors", Json::usize_arr(&[0, 2, 8])),
            ("frac", Json::num(0.1)),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("n", Json::Null)])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_whitespace_and_negatives() {
        let j = Json::parse(" { \"a\" : [ -1.5 , 2e3, 0 ] }\n").unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
        let parsed = Json::parse(r#""xAy""#).unwrap();
        assert_eq!(parsed.as_str(), Some("xAy"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn real_manifest_fragment() {
        let s = r#"{"config": {"n_layers": 16, "d_model": 256},
                    "artifacts": {"embed_decode": {"file": "embed_decode.hlo.txt",
                    "inputs": [{"shape": [1], "dtype": "int32"}]}}}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.req("config").unwrap().req("n_layers").unwrap().as_usize(), Some(16));
        let art = j.get("artifacts").unwrap().get("embed_decode").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("embed_decode.hlo.txt"));
        assert_eq!(
            art.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .usize_vec()
                .unwrap(),
            vec![1]
        );
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::Str("héllo → 世界".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn dotted_path_walks_and_misses() {
        let j = Json::parse(r#"{"a":{"b":{"c":3}},"flat":1}"#).unwrap();
        assert_eq!(j.path("a.b.c").unwrap().as_usize(), Some(3));
        assert_eq!(j.path("flat").unwrap().as_usize(), Some(1));
        assert!(j.path("a.b.missing").is_none());
        assert!(j.path("a.b.c.deeper").is_none(), "scalar has no children");
        assert!(j.path("nope").is_none());
    }

    #[test]
    fn from_file_reports_path_on_missing_and_malformed() {
        let missing = Json::from_file(std::path::Path::new("/nonexistent/kascade.json"));
        let msg = format!("{:#}", missing.unwrap_err());
        assert!(msg.contains("/nonexistent/kascade.json"), "error names the file: {msg}");

        let dir = std::env::temp_dir().join("kascade_jsonutil_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("malformed.json");
        std::fs::write(&bad, "{\"results\": [1, 2,}").unwrap();
        let err = Json::from_file(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("malformed.json"), "error names the file: {msg}");
        assert!(msg.contains("byte"), "parse error keeps its position: {msg}");
        std::fs::remove_file(&bad).ok();
    }
}
