//! Small statistics helpers: Welford accumulator, latency histogram, timer.

use std::time::Instant;

/// Online mean/variance (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Fold another accumulator in (Chan et al. parallel combine): the
    /// result is exactly what one accumulator fed both streams would
    /// hold, so per-worker metrics can merge into a fleet view.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket log-scale latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>, // powers of 2 from 1us
    samples: Vec<f64>,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], samples: Vec::new() }
    }

    pub fn add_us(&mut self, us: f64) {
        let b = (us.max(1.0).log2() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.samples.push(us);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Exact percentile from retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let i = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[i.min(s.len() - 1)]
    }

    /// Fold another histogram in: bucket counts add, retained samples
    /// extend, so percentiles over the merged histogram are exact over
    /// the union of both sample streams.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += *o;
        }
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Scoped wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_moments() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.add(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-9);
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut whole = Welford::new();
        let (mut a, mut b) = (Welford::new(), Welford::new());
        for (i, &x) in xs.iter().enumerate() {
            whole.add(x);
            if i < 3 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.var() - whole.var()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // empty operands on either side are identity
        let mut empty = Welford::new();
        empty.merge(&whole);
        assert!((empty.mean() - whole.mean()).abs() < 1e-12);
        empty.merge(&Welford::new());
        assert_eq!(empty.count(), whole.count());
    }

    #[test]
    fn histogram_merge_matches_single_stream() {
        let mut whole = LatencyHist::new();
        let (mut a, mut b) = (LatencyHist::new(), LatencyHist::new());
        for i in 1..=100 {
            whole.add_us(i as f64);
            if i % 2 == 0 { a.add_us(i as f64) } else { b.add_us(i as f64) }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.percentile(50.0) - whole.percentile(50.0)).abs() < 1e-9);
        assert!((a.percentile(95.0) - whole.percentile(95.0)).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.add_us(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
    }
}
