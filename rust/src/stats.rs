//! Small statistics helpers: Welford accumulator, latency histogram, timer.

use std::time::Instant;

/// Online mean/variance (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Fixed-bucket log-scale latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>, // powers of 2 from 1us
    samples: Vec<f64>,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], samples: Vec::new() }
    }

    pub fn add_us(&mut self, us: f64) {
        let b = (us.max(1.0).log2() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.samples.push(us);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Exact percentile from retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let i = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[i.min(s.len() - 1)]
    }
}

/// Scoped wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_moments() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.add(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-9);
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.add_us(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
    }
}
