//! Continuous batcher: per-tick work formation under a token budget, with
//! block-manager-gated admission and recompute-style preemption.
//!
//! Policy (vLLM-like):
//! 1. every running decode gets one token (decodes are latency-critical);
//!    if a decode cannot get its block, preempt the *youngest* running
//!    sequence until it can;
//! 2. remaining budget admits prefill chunks (chunked prefill), oldest
//!    waiting first, gated on block availability and `max_running`.

use super::blocks::BlockManager;
use super::sequence::{SeqPhase, Sequence};
use crate::config::ServeConfig;
use std::collections::VecDeque;

/// One unit of scheduled work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkItem {
    Prefill { seq: u64, tokens: usize },
    Decode { seq: u64 },
}

/// The work selected for one tick.
#[derive(Debug, Default)]
pub struct Batch {
    pub items: Vec<WorkItem>,
    pub preempted: Vec<u64>,
    pub budget_used: usize,
}

pub struct Scheduler {
    pub cfg: ServeConfig,
    pub blocks: BlockManager,
    pub waiting: VecDeque<u64>,
    pub running: Vec<u64>,
    /// sequences rejected at admission (queue full)
    pub rejected: u64,
}

impl Scheduler {
    pub fn new(cfg: ServeConfig) -> Self {
        let blocks = BlockManager::new(cfg.block_size, cfg.num_blocks);
        Self { cfg, blocks, waiting: VecDeque::new(), running: Vec::new(), rejected: 0 }
    }

    /// Admission control.  Returns false when the waiting queue is full.
    pub fn submit(&mut self, seq: u64) -> bool {
        if self.waiting.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return false;
        }
        self.waiting.push_back(seq);
        true
    }

    pub fn on_finished(&mut self, seq: u64) {
        self.running.retain(|&s| s != seq);
        self.blocks.release(seq);
    }

    /// Form one tick's batch.  `seqs` gives phase/size info per id.
    pub fn tick<F>(&mut self, lookup: F) -> Batch
    where
        F: Fn(u64) -> Option<(SeqPhase, usize, usize)>, // (phase, prompt_len, total_tokens)
    {
        let mut batch = Batch::default();
        let mut budget = self.cfg.token_budget;

        // 1. decodes: one token each, preempting youngest on OOM
        let decode_ids: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|&id| matches!(lookup(id), Some((SeqPhase::Decoding, _, _))))
            .collect();
        for id in decode_ids {
            if budget == 0 {
                break;
            }
            if batch.preempted.contains(&id) {
                continue;
            }
            let total = self.blocks.tokens_of(id) + 1;
            while !self.blocks.can_extend(id, total) {
                // preempt the youngest running sequence that isn't `id`
                let victim = match self.running.iter().rev().copied().find(|&v| v != id) {
                    Some(v) => v,
                    None => break,
                };
                self.preempt(victim, &mut batch);
            }
            if self.blocks.extend(id, total) {
                batch.items.push(WorkItem::Decode { seq: id });
                budget -= 1;
            }
        }

        // 2. running prefills continue (chunked), oldest first
        let prefill_ids: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|&id| matches!(lookup(id), Some((SeqPhase::Prefilling { .. }, _, _))))
            .collect();
        for id in prefill_ids {
            if budget == 0 {
                break;
            }
            if batch.preempted.contains(&id) {
                continue;
            }
            if let Some((SeqPhase::Prefilling { done }, prompt_len, _)) = lookup(id) {
                let take = self.cfg.prefill_chunk.min(prompt_len - done).min(budget);
                if take == 0 {
                    continue;
                }
                if self.blocks.extend(id, done + take) {
                    batch.items.push(WorkItem::Prefill { seq: id, tokens: take });
                    budget -= take;
                }
            }
        }

        // 3. admit new sequences from the waiting queue
        while budget > 0 && self.running.len() < self.cfg.max_running {
            let id = match self.waiting.front().copied() {
                Some(id) => id,
                None => break,
            };
            let (phase, prompt_len, _) = match lookup(id) {
                Some(x) => x,
                None => {
                    self.waiting.pop_front();
                    continue;
                }
            };
            debug_assert!(matches!(phase, SeqPhase::Waiting));
            let take = self.cfg.prefill_chunk.min(prompt_len).min(budget);
            if !self.blocks.extend(id, take) {
                break; // no memory: stop admitting (FCFS, no head-of-line skip)
            }
            self.waiting.pop_front();
            self.running.push(id);
            batch.items.push(WorkItem::Prefill { seq: id, tokens: take });
            budget -= take;
        }

        batch.budget_used = self.cfg.token_budget - budget;
        batch
    }

    fn preempt(&mut self, victim: u64, batch: &mut Batch) {
        self.blocks.release(victim);
        self.running.retain(|&s| s != victim);
        self.waiting.push_front(victim);
        batch.preempted.push(victim);
        // drop any work already scheduled for the victim this tick
        batch.items.retain(|w| match w {
            WorkItem::Prefill { seq, .. } | WorkItem::Decode { seq } => *seq != victim,
        });
    }

    /// Apply a finished tick: mark sequences that completed.
    pub fn retire_finished(&mut self, seqs: &mut std::collections::HashMap<u64, Sequence>) {
        let finished: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| seqs.get(id).map(|s| s.is_finished()).unwrap_or(true))
            .collect();
        for id in finished {
            self.on_finished(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::proptest_lite::check;
    use std::collections::HashMap;

    fn cfg() -> ServeConfig {
        ServeConfig {
            block_size: 16,
            num_blocks: 64, // 1024 tokens
            max_running: 8,
            token_budget: 256,
            prefill_chunk: 128,
            queue_cap: 16,
            workers: 1,
        }
    }

    /// simple simulated world: phase table driven by applied work
    struct World {
        phases: HashMap<u64, (SeqPhase, usize, usize)>,
    }

    impl World {
        fn lookup(&self) -> impl Fn(u64) -> Option<(SeqPhase, usize, usize)> + '_ {
            move |id| self.phases.get(&id).copied()
        }
    }

    #[test]
    fn admits_and_chunks_prefill() {
        let mut s = Scheduler::new(cfg());
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Waiting, 300, 0));
        s.submit(1);
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 128 }]);
        // apply
        w.phases.insert(1, (SeqPhase::Prefilling { done: 128 }, 300, 128));
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 128 }]);
        w.phases.insert(1, (SeqPhase::Prefilling { done: 256 }, 300, 256));
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 44 }]);
    }

    #[test]
    fn decodes_have_priority_over_admission() {
        let mut s = Scheduler::new(ServeConfig { token_budget: 4, ..cfg() });
        let mut w = World { phases: HashMap::new() };
        for id in 1..=3u64 {
            w.phases.insert(id, (SeqPhase::Decoding, 10, 10));
            s.running.push(id);
            s.blocks.extend(id, 10);
        }
        w.phases.insert(9, (SeqPhase::Waiting, 100, 0));
        s.submit(9);
        let b = s.tick(w.lookup());
        let decodes = b.items.iter().filter(|i| matches!(i, WorkItem::Decode { .. })).count();
        assert_eq!(decodes, 3);
        // remaining budget (1 token) goes to the new prefill
        assert!(b.items.contains(&WorkItem::Prefill { seq: 9, tokens: 1 }));
    }

    #[test]
    fn preempts_youngest_on_oom() {
        let mut s = Scheduler::new(ServeConfig { num_blocks: 4, ..cfg() }); // 64 tokens
        let mut w = World { phases: HashMap::new() };
        // old sequence decoding at a block boundary, young one hoarding
        w.phases.insert(1, (SeqPhase::Decoding, 16, 16));
        w.phases.insert(2, (SeqPhase::Decoding, 48, 48));
        s.running.push(1);
        s.running.push(2);
        s.blocks.extend(1, 16); // 1 block, full
        s.blocks.extend(2, 48); // 3 blocks
        let b = s.tick(w.lookup());
        // seq 1 needs a new block; none free -> preempt youngest (2)
        assert_eq!(b.preempted, vec![2]);
        assert!(b.items.contains(&WorkItem::Decode { seq: 1 }));
        assert!(!b.items.contains(&WorkItem::Decode { seq: 2 }));
        assert!(s.waiting.contains(&2));
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn queue_cap_rejects() {
        let mut s = Scheduler::new(ServeConfig { queue_cap: 2, ..cfg() });
        assert!(s.submit(1));
        assert!(s.submit(2));
        assert!(!s.submit(3));
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn prop_budget_and_block_invariants_hold() {
        check("scheduler invariants", 20, |rng| {
            let c = ServeConfig {
                block_size: 1 + rng.below(16),
                num_blocks: 8 + rng.below(64),
                max_running: 1 + rng.below(8),
                token_budget: 16 + rng.below(256),
                prefill_chunk: 1 + rng.below(128),
                queue_cap: 64,
                workers: 1,
            };
            let budget = c.token_budget;
            let mut s = Scheduler::new(c);
            let mut phases: HashMap<u64, (SeqPhase, usize, usize)> = HashMap::new();
            let mut next_id = 0u64;
            for step in 0..60 {
                // random arrivals
                for _ in 0..rng.below(3) {
                    next_id += 1;
                    phases.insert(next_id, (SeqPhase::Waiting, 1 + rng.below(400), 0));
                    s.submit(next_id);
                }
                let batch = {
                    let ph = phases.clone();
                    s.tick(move |id| ph.get(&id).copied())
                };
                prop_assert!(
                    batch.budget_used <= budget,
                    "step {step}: budget {} > {budget}",
                    batch.budget_used
                );
                // at most one work item per sequence per tick
                let mut seen = std::collections::HashSet::new();
                for it in &batch.items {
                    let id = match it {
                        WorkItem::Prefill { seq, .. } | WorkItem::Decode { seq } => *seq,
                    };
                    prop_assert!(seen.insert(id), "step {step}: duplicate work for {id}");
                }
                if let Err(e) = s.blocks.check_invariants() {
                    return Err(format!("step {step}: {e}"));
                }
                // apply work
                for it in &batch.items {
                    match *it {
                        WorkItem::Prefill { seq, tokens } => {
                            let (ph, plen, tot) = phases[&seq];
                            let done = match ph {
                                SeqPhase::Waiting => 0,
                                SeqPhase::Prefilling { done } => done,
                                _ => continue,
                            };
                            let nd = done + tokens;
                            let nph = if nd >= plen { SeqPhase::Decoding } else { SeqPhase::Prefilling { done: nd } };
                            phases.insert(seq, (nph, plen, tot + tokens));
                        }
                        WorkItem::Decode { seq } => {
                            let (_, plen, tot) = phases[&seq];
                            // finish with probability ~1/8
                            if rng.below(8) == 0 {
                                phases.remove(&seq);
                                s.on_finished(seq);
                            } else {
                                phases.insert(seq, (SeqPhase::Decoding, plen, tot + 1));
                            }
                        }
                    }
                }
                for p in batch.preempted {
                    if let Some(e) = phases.get_mut(&p) {
                        *e = (SeqPhase::Waiting, e.1 + (e.2), 0);
                    }
                }
            }
            Ok(())
        });
    }
}
