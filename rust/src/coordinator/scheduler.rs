//! Continuous batcher: per-tick work formation under a token budget, with
//! block-manager-gated admission, recompute-style preemption and
//! automatic prefix caching.
//!
//! Policy (vLLM-like):
//! 1. every running decode gets one token (decodes are latency-critical);
//!    if a decode cannot get its block, preempt the *youngest* running
//!    sequence until it can;
//! 2. remaining budget admits prefill chunks (chunked prefill), oldest
//!    waiting first, gated on block availability and `max_running`;
//!    admission reserves blocks for the whole prompt up front, so a
//!    half-prefilled sequence can never deadlock the pool.
//!
//! With `enable_prefix_cache`, admission first matches the prompt's
//! block-chain hashes against the [`PrefixIndex`]: a hit adopts the
//! cached blocks (refcount sharing, no KV storage) and the first prefill
//! chunk starts at the first uncached token (no prefill compute for the
//! shared prefix — the engine resumes from a state snapshot keyed by the
//! matched chain hash).  Preemption drops refs, not blocks: a preempted
//! sequence's indexed blocks park in the cached pool and are typically
//! re-adopted wholesale when it is re-admitted.
//!
//! Two traffic-facing refinements (both off by default):
//!
//! * **decode-tick protection** (`decode_guard_prefill_tokens`): when the
//!   tick schedules any decode, total prefill tokens in the same tick are
//!   capped, so a 128k-token prefill advances in small slices between
//!   decode steps instead of absorbing the whole `token_budget` — this
//!   bounds tick wall time and TPOT jitter under long-context ingest;
//! * **fair-share admission** (`fair_share`): among the highest-priority
//!   non-recovering waiters, admit the request whose tenant has the
//!   smallest admitted-prompt-token account, so one tenant flooding the
//!   queue cannot starve the rest (priority and preemption recovery
//!   still dominate).

use super::blocks::BlockManager;
use super::prefix_cache::{chain_hashes, PrefixIndex};
use super::sequence::{SeqPhase, Sequence};
use crate::config::ServeConfig;
use std::collections::{HashMap, HashSet, VecDeque};

/// One unit of scheduled work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkItem {
    Prefill { seq: u64, tokens: usize },
    Decode { seq: u64 },
}

/// The work selected for one tick.
#[derive(Debug, Default)]
pub struct Batch {
    pub items: Vec<WorkItem>,
    pub preempted: Vec<u64>,
    /// freshly admitted sequences that adopted a cached prefix:
    /// `(seq, cached_tokens, snapshot_hash)` — the engine fast-forwards
    /// the sequence to `cached_tokens` from the snapshot under the hash
    pub cache_hits: Vec<(u64, usize, u64)>,
    /// admissions that found no usable cached prefix (cache enabled)
    pub cache_misses: u64,
    pub budget_used: usize,
}

impl Batch {
    /// Total prefill tokens scheduled this tick (the quantity
    /// `decode_guard_prefill_tokens` bounds when decodes are present).
    pub fn prefill_tokens(&self) -> usize {
        self.items
            .iter()
            .map(|it| match it {
                WorkItem::Prefill { tokens, .. } => *tokens,
                WorkItem::Decode { .. } => 0,
            })
            .sum()
    }

    /// Whether any decode was scheduled this tick.
    pub fn has_decodes(&self) -> bool {
        self.items.iter().any(|it| matches!(it, WorkItem::Decode { .. }))
    }
}

pub struct Scheduler {
    pub cfg: ServeConfig,
    pub blocks: BlockManager,
    pub prefix: PrefixIndex,
    pub waiting: VecDeque<u64>,
    pub running: Vec<u64>,
    /// sequences rejected at admission (queue full)
    pub rejected: u64,
    /// per-sequence chain hashes of the prompt's full blocks
    hashes: HashMap<u64, Vec<u64>>,
    /// per-sequence count of prompt blocks already registered
    registered: HashMap<u64, usize>,
    /// per-sequence admission priority (default 0; higher admits first)
    priorities: HashMap<u64, i32>,
    /// sequences parked at the queue head for preemption recovery —
    /// they keep their slot regardless of later submits' priorities
    recovering: HashSet<u64>,
    /// per-sequence tenant id (default 0), for fair-share accounting
    tenants: HashMap<u64, u32>,
    /// cumulative admitted prompt tokens per tenant — the fair-share
    /// "debt" account; admission picks the least-indebted tenant among
    /// the top-priority waiters
    tenant_debt: HashMap<u32, u64>,
}

impl Scheduler {
    pub fn new(cfg: ServeConfig) -> Self {
        // compressed KV (f16/int8/int4) relies on block-aligned
        // boundaries (prefix snapshots, CoW forks) landing on
        // conversion-tile edges; the tile is the KvCache page (16).  A
        // misaligned block size would silently re-convert forked tails —
        // refuse it up front.
        assert!(
            !cfg.kv_dtype.is_compressed() || cfg.block_size % 16 == 0,
            "kv_dtype={} requires block_size to be a multiple of the 16-token \
             conversion tile (got {})",
            cfg.kv_dtype.label(),
            cfg.block_size
        );
        // tiered KV demotes/promotes whole int8 quantization tiles — the
        // tier machinery has no f32 representation to spill
        assert!(
            !cfg.kv_tiers || cfg.kv_dtype == crate::config::KvDtype::Int8,
            "kv_tiers requires kv_dtype=int8 (tiles spill as int8 payloads)"
        );
        let mut blocks = BlockManager::new(cfg.block_size, cfg.num_blocks);
        blocks.set_dtype(cfg.kv_dtype);
        if cfg.enable_prefix_cache {
            blocks.set_cache_capacity(cfg.prefix_cache_blocks);
        }
        if cfg.kv_tiers {
            blocks.set_tile_budget(cfg.hot_tile_budget);
        }
        Self {
            cfg,
            blocks,
            prefix: PrefixIndex::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            rejected: 0,
            hashes: HashMap::new(),
            registered: HashMap::new(),
            priorities: HashMap::new(),
            recovering: HashSet::new(),
            tenants: HashMap::new(),
            tenant_debt: HashMap::new(),
        }
    }

    /// Admission control at default priority.  Returns false when the
    /// waiting queue is full.
    pub fn submit(&mut self, seq: u64) -> bool {
        self.submit_prio(seq, 0)
    }

    /// Admission control with an explicit priority: the sequence queues
    /// ahead of every strictly-lower-priority waiter (stable FCFS within
    /// a priority level).  Returns false when the waiting queue is full.
    pub fn submit_prio(&mut self, seq: u64, priority: i32) -> bool {
        if self.waiting.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return false;
        }
        self.priorities.insert(seq, priority);
        let prios = &self.priorities;
        // never jump a preemption-recovery waiter: it keeps its
        // head-of-queue slot no matter the submitter's priority
        let rec = &self.recovering;
        let at = self
            .waiting
            .iter()
            .position(|w| !rec.contains(w) && prios.get(w).copied().unwrap_or(0) < priority)
            .unwrap_or(self.waiting.len());
        self.waiting.insert(at, seq);
        true
    }

    /// Submit with the prompt tokens so the prefix cache can match them.
    pub fn submit_with_prompt(&mut self, seq: u64, prompt: &[u32]) -> bool {
        self.submit_request(seq, prompt, 0)
    }

    /// Full typed admission: prompt (for prefix matching) + priority.
    pub fn submit_request(&mut self, seq: u64, prompt: &[u32], priority: i32) -> bool {
        if !self.submit_prio(seq, priority) {
            return false;
        }
        self.set_prompt(seq, prompt);
        true
    }

    /// (Re)compute `seq`'s prompt block hashes.  Must be called again
    /// after preemption folds emitted tokens into the prompt.
    pub fn set_prompt(&mut self, seq: u64, prompt: &[u32]) {
        if !self.cfg.enable_prefix_cache {
            return;
        }
        self.hashes.insert(seq, chain_hashes(prompt, self.cfg.block_size));
        self.registered.insert(seq, 0);
    }

    /// Tag `seq` with its tenant for fair-share admission.  Untagged
    /// sequences belong to tenant 0.  Recorded unconditionally (cheap);
    /// consulted only when `cfg.fair_share` is set.
    pub fn set_tenant(&mut self, seq: u64, tenant: u32) {
        self.tenants.insert(seq, tenant);
    }

    /// Cumulative admitted prompt tokens charged to `tenant`.
    pub fn tenant_debt(&self, tenant: u32) -> u64 {
        self.tenant_debt.get(&tenant).copied().unwrap_or(0)
    }

    pub fn on_finished(&mut self, seq: u64) {
        self.remove(seq);
    }

    /// Remove a sequence wherever it lives — waiting queue, running set,
    /// or both-neither — releasing every block it holds.  This is the
    /// cancellation/deadline teardown path: indexed blocks park in the
    /// prefix-cache pool (refcounts drop, content survives), so
    /// engine-held snapshots for boundaries the sequence registered stay
    /// valid for future admissions.
    pub fn remove(&mut self, seq: u64) {
        self.running.retain(|&s| s != seq);
        self.waiting.retain(|&s| s != seq);
        self.blocks.release(seq);
        self.hashes.remove(&seq);
        self.registered.remove(&seq);
        self.priorities.remove(&seq);
        self.recovering.remove(&seq);
        self.tenants.remove(&seq);
    }

    /// Register `seq`'s first `boundary / block_size` full prompt blocks
    /// in the prefix index (engine-driven, after prefill work applies;
    /// `boundary` is block-aligned).  With `resumable`, the boundary's
    /// chain hash is flagged as a resume point — the engine stores a
    /// backend state snapshot under the returned hash.
    pub fn register_prefix(&mut self, seq: u64, boundary: usize, resumable: bool) -> Option<u64> {
        if !self.cfg.enable_prefix_cache || boundary == 0 {
            return None;
        }
        debug_assert_eq!(boundary % self.cfg.block_size, 0);
        let nb = boundary / self.cfg.block_size;
        let hs = self.hashes.get(&seq)?.clone();
        if nb > hs.len() {
            return None;
        }
        let start = self.registered.get(&seq).copied().unwrap_or(0);
        for (j, &h) in hs.iter().enumerate().take(nb).skip(start) {
            if let Some(b) = self.blocks.block_of(seq, j) {
                if self.prefix.register(h, b) {
                    self.blocks.mark_indexed(b);
                }
            }
        }
        let cur = self.registered.entry(seq).or_insert(0);
        *cur = (*cur).max(nb);
        let h = hs[nb - 1];
        if resumable {
            self.prefix.mark_resumable(h);
        }
        Some(h)
    }

    /// Whether the engine should snapshot `seq`'s state at the
    /// block-aligned `boundary`: the boundary hash, unless it is already
    /// a live resume point.
    pub fn snapshot_wanted(&self, seq: u64, boundary: usize) -> Option<u64> {
        if !self.cfg.enable_prefix_cache || boundary == 0 {
            return None;
        }
        let nb = boundary / self.cfg.block_size;
        let hs = self.hashes.get(&seq)?;
        if nb == 0 || nb > hs.len() {
            return None;
        }
        let h = hs[nb - 1];
        if self.prefix.is_resumable(h) {
            None
        } else {
            Some(h)
        }
    }

    /// Sync index entries with block evictions; returns the chain hashes
    /// whose engine-side snapshots must be dropped.
    pub fn take_invalidated(&mut self) -> Vec<u64> {
        for b in self.blocks.take_evicted() {
            self.prefix.forget_block(b);
        }
        self.prefix.drain_invalidated()
    }

    /// Form one tick's batch.  `seqs` gives phase/size info per id.
    pub fn tick<F>(&mut self, lookup: F) -> Batch
    where
        F: Fn(u64) -> Option<(SeqPhase, usize, usize)>, // (phase, prompt_len, total_tokens)
    {
        let mut batch = Batch::default();
        let mut budget = self.cfg.token_budget;

        // 1. decodes: one token each, preempting youngest on OOM
        let decode_ids: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|&id| matches!(lookup(id), Some((SeqPhase::Decoding, _, _))))
            .collect();
        for id in decode_ids {
            if budget == 0 {
                break;
            }
            if batch.preempted.contains(&id) {
                continue;
            }
            let total = self.blocks.tokens_of(id) + 1;
            while !self.blocks.can_extend(id, total) {
                // preempt the youngest running sequence that isn't `id`
                let victim = match self.running.iter().rev().copied().find(|&v| v != id) {
                    Some(v) => v,
                    None => break,
                };
                self.preempt(victim, &mut batch);
            }
            if self.blocks.extend(id, total) {
                batch.items.push(WorkItem::Decode { seq: id });
                budget -= 1;
            }
        }

        // decode-tick protection: when this tick schedules decodes, cap
        // the total prefill tokens it may also schedule — a huge
        // in-flight prefill then advances in bounded slices between
        // decode steps instead of absorbing the whole token budget
        let mut prefill_cap = match self.cfg.decode_guard_prefill_tokens {
            Some(cap) if batch.has_decodes() => cap,
            _ => usize::MAX,
        };

        // 2. running prefills continue (chunked), oldest first
        let prefill_ids: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|&id| matches!(lookup(id), Some((SeqPhase::Prefilling { .. }, _, _))))
            .collect();
        for id in prefill_ids {
            if budget == 0 {
                break;
            }
            if batch.preempted.contains(&id) {
                continue;
            }
            if let Some((SeqPhase::Prefilling { done }, prompt_len, _)) = lookup(id) {
                let take = self.cfg.prefill_chunk.min(prompt_len - done).min(budget).min(prefill_cap);
                if take == 0 {
                    continue;
                }
                // blocks were reserved for the whole prompt at admission,
                // so continuation never allocates (and never deadlocks
                // half-prefilled); keep the reservation monotone
                let reserved = self.blocks.tokens_of(id);
                if self.blocks.extend(id, reserved.max(done + take)) {
                    batch.items.push(WorkItem::Prefill { seq: id, tokens: take });
                    budget -= take;
                    prefill_cap = prefill_cap.saturating_sub(take);
                }
            }
        }

        // 3. admit new sequences from the waiting queue
        while budget > 0 && prefill_cap > 0 && self.running.len() < self.cfg.max_running {
            let pos = match self.admission_pos() {
                Some(p) => p,
                None => break,
            };
            let id = self.waiting[pos];
            let (phase, prompt_len, _) = match lookup(id) {
                Some(x) => x,
                None => {
                    self.waiting.remove(pos);
                    self.recovering.remove(&id);
                    continue;
                }
            };
            if !matches!(phase, SeqPhase::Waiting) {
                // preempted earlier this very tick: its phase resets only
                // once the batch applies — keep it queued (FCFS) and
                // re-admit next tick
                break;
            }
            // prefix-cache match: adopt shared blocks, start prefill at
            // the first uncached token
            let mut cached = 0usize;
            let mut hit: Option<u64> = None;
            if self.cfg.enable_prefix_cache && self.blocks.tokens_of(id) == 0 {
                if let Some(hs) = self.hashes.get(&id) {
                    let limit = prompt_len.saturating_sub(1) / self.cfg.block_size;
                    let bm = &self.blocks;
                    if let Some(m) = self.prefix.lookup(hs, limit, |b| bm.is_adoptable(b)) {
                        cached = m.blocks.len() * self.cfg.block_size;
                        self.blocks.adopt(id, &m.blocks, cached);
                        hit = Some(m.hash);
                    }
                }
            }
            let take = self.cfg.prefill_chunk.min(prompt_len - cached).min(budget).min(prefill_cap);
            // reserve blocks for the WHOLE prompt up front (vLLM-style):
            // a sequence that admits can always finish its prefill, so
            // half-prefilled sequences can never deadlock the pool
            if !self.blocks.extend(id, prompt_len) {
                if hit.is_some() {
                    // roll the adoption back (refs return to the pool)
                    self.blocks.release(id);
                }
                break; // no memory: stop admitting (FCFS, no head-of-line skip)
            }
            self.waiting.remove(pos);
            self.recovering.remove(&id);
            self.running.push(id);
            let tenant = self.tenants.get(&id).copied().unwrap_or(0);
            *self.tenant_debt.entry(tenant).or_insert(0) += prompt_len as u64;
            if let Some(h) = hit {
                batch.cache_hits.push((id, cached, h));
                self.prefix.stats.hits += 1;
                self.prefix.stats.saved_tokens += cached as u64;
            } else if self.cfg.enable_prefix_cache {
                batch.cache_misses += 1;
                self.prefix.stats.misses += 1;
            }
            batch.items.push(WorkItem::Prefill { seq: id, tokens: take });
            budget -= take;
            prefill_cap = prefill_cap.saturating_sub(take);
        }

        batch.budget_used = self.cfg.token_budget - budget;
        batch
    }

    /// Position in `waiting` of the next admission candidate.
    ///
    /// FCFS (`Some(0)`) unless fair-share is on: then, among the leading
    /// run of equal-top-priority non-recovering waiters, the request
    /// whose tenant holds the smallest admitted-token account wins (ties
    /// break toward the earlier submit).  A recovering victim at the
    /// head always keeps its slot.
    fn admission_pos(&self) -> Option<usize> {
        let &front = self.waiting.front()?;
        if !self.cfg.fair_share || self.recovering.contains(&front) {
            return Some(0);
        }
        let p0 = self.priorities.get(&front).copied().unwrap_or(0);
        let mut best = 0usize;
        let mut best_debt = self.tenant_debt(self.tenants.get(&front).copied().unwrap_or(0));
        for (i, w) in self.waiting.iter().enumerate().skip(1) {
            if self.recovering.contains(w) || self.priorities.get(w).copied().unwrap_or(0) != p0 {
                break;
            }
            let debt = self.tenant_debt(self.tenants.get(w).copied().unwrap_or(0));
            if debt < best_debt {
                best = i;
                best_debt = debt;
            }
        }
        Some(best)
    }

    fn preempt(&mut self, victim: u64, batch: &mut Batch) {
        // drop refs, not blocks: indexed blocks park in the cached pool
        self.blocks.release(victim);
        self.registered.insert(victim, 0);
        self.running.retain(|&s| s != victim);
        self.recovering.insert(victim);
        self.waiting.push_front(victim);
        batch.preempted.push(victim);
        // drop any work already scheduled for the victim this tick
        batch.items.retain(|w| match w {
            WorkItem::Prefill { seq, .. } | WorkItem::Decode { seq } => *seq != victim,
        });
        batch.cache_hits.retain(|&(seq, _, _)| seq != victim);
    }

    /// Apply a finished tick: mark sequences that completed.
    pub fn retire_finished(&mut self, seqs: &mut std::collections::HashMap<u64, Sequence>) {
        let finished: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| seqs.get(id).map(|s| s.is_finished()).unwrap_or(true))
            .collect();
        for id in finished {
            self.on_finished(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::proptest_lite::check;
    use std::collections::HashMap;

    fn cfg() -> ServeConfig {
        ServeConfig {
            block_size: 16,
            num_blocks: 64, // 1024 tokens
            max_running: 8,
            token_budget: 256,
            prefill_chunk: 128,
            queue_cap: 16,
            workers: 1,
            ..ServeConfig::default()
        }
    }

    /// simple simulated world: phase table driven by applied work
    struct World {
        phases: HashMap<u64, (SeqPhase, usize, usize)>,
    }

    impl World {
        fn lookup(&self) -> impl Fn(u64) -> Option<(SeqPhase, usize, usize)> + '_ {
            move |id| self.phases.get(&id).copied()
        }
    }

    #[test]
    fn admits_and_chunks_prefill() {
        let mut s = Scheduler::new(cfg());
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Waiting, 300, 0));
        s.submit(1);
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 128 }]);
        // apply
        w.phases.insert(1, (SeqPhase::Prefilling { done: 128 }, 300, 128));
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 128 }]);
        w.phases.insert(1, (SeqPhase::Prefilling { done: 256 }, 300, 256));
        let b = s.tick(w.lookup());
        assert_eq!(b.items, vec![WorkItem::Prefill { seq: 1, tokens: 44 }]);
    }

    #[test]
    fn decodes_have_priority_over_admission() {
        let mut s = Scheduler::new(ServeConfig { token_budget: 4, ..cfg() });
        let mut w = World { phases: HashMap::new() };
        for id in 1..=3u64 {
            w.phases.insert(id, (SeqPhase::Decoding, 10, 10));
            s.running.push(id);
            s.blocks.extend(id, 10);
        }
        w.phases.insert(9, (SeqPhase::Waiting, 100, 0));
        s.submit(9);
        let b = s.tick(w.lookup());
        let decodes = b.items.iter().filter(|i| matches!(i, WorkItem::Decode { .. })).count();
        assert_eq!(decodes, 3);
        // remaining budget (1 token) goes to the new prefill
        assert!(b.items.contains(&WorkItem::Prefill { seq: 9, tokens: 1 }));
    }

    #[test]
    fn preempts_youngest_on_oom() {
        let mut s = Scheduler::new(ServeConfig { num_blocks: 4, ..cfg() }); // 64 tokens
        let mut w = World { phases: HashMap::new() };
        // old sequence decoding at a block boundary, young one hoarding
        w.phases.insert(1, (SeqPhase::Decoding, 16, 16));
        w.phases.insert(2, (SeqPhase::Decoding, 48, 48));
        s.running.push(1);
        s.running.push(2);
        s.blocks.extend(1, 16); // 1 block, full
        s.blocks.extend(2, 48); // 3 blocks
        let b = s.tick(w.lookup());
        // seq 1 needs a new block; none free -> preempt youngest (2)
        assert_eq!(b.preempted, vec![2]);
        assert!(b.items.contains(&WorkItem::Decode { seq: 1 }));
        assert!(!b.items.contains(&WorkItem::Decode { seq: 2 }));
        assert!(s.waiting.contains(&2));
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn queue_cap_rejects() {
        let mut s = Scheduler::new(ServeConfig { queue_cap: 2, ..cfg() });
        assert!(s.submit(1));
        assert!(s.submit(2));
        assert!(!s.submit(3));
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn higher_priority_jumps_the_queue_stably() {
        let mut s = Scheduler::new(cfg());
        assert!(s.submit_prio(1, 0));
        assert!(s.submit_prio(2, 0));
        assert!(s.submit_prio(3, 5));
        assert!(s.submit_prio(4, 5));
        assert!(s.submit_prio(5, 1));
        // priority desc, FCFS within a level
        assert_eq!(s.waiting.iter().copied().collect::<Vec<_>>(), vec![3, 4, 5, 1, 2]);
    }

    #[test]
    fn priority_admission_order() {
        let mut s = Scheduler::new(ServeConfig { max_running: 1, ..cfg() });
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Waiting, 32, 0));
        w.phases.insert(2, (SeqPhase::Waiting, 32, 0));
        s.submit_prio(1, 0);
        s.submit_prio(2, 3);
        let b = s.tick(w.lookup());
        assert!(
            b.items.contains(&WorkItem::Prefill { seq: 2, tokens: 32 }),
            "high-priority request must admit first: {:?}",
            b.items
        );
        assert!(s.waiting.contains(&1));
    }

    /// A later submit must not jump a preempted sequence's recovery
    /// slot — and higher-priority waiters queued behind it still outrank
    /// the newcomer.
    #[test]
    fn submit_cannot_jump_a_preemption_recovery_slot() {
        let mut s = Scheduler::new(ServeConfig { num_blocks: 4, ..cfg() }); // 64 tokens
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Decoding, 16, 16));
        w.phases.insert(2, (SeqPhase::Decoding, 48, 48));
        s.running.push(1);
        s.running.push(2);
        s.blocks.extend(1, 16);
        s.blocks.extend(2, 48);
        s.submit_prio(3, 5); // high-priority waiter
        let b = s.tick(w.lookup());
        assert_eq!(b.preempted, vec![2], "OOM preempts the youngest");
        assert_eq!(s.waiting.front(), Some(&2), "victim parks at the head");
        // mid-priority submit: behind the recovering victim AND behind
        // the strictly-higher-priority waiter
        s.submit_prio(4, 1);
        assert_eq!(s.waiting.iter().copied().collect::<Vec<_>>(), vec![2, 3, 4]);
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn decode_guard_caps_prefill_tokens_when_decoding() {
        let mut s =
            Scheduler::new(ServeConfig { decode_guard_prefill_tokens: Some(8), ..cfg() });
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Decoding, 16, 16));
        w.phases.insert(2, (SeqPhase::Prefilling { done: 128 }, 500, 128));
        s.running.push(1);
        s.running.push(2);
        s.blocks.extend(1, 16);
        s.blocks.extend(2, 500);
        let b = s.tick(w.lookup());
        assert!(b.items.contains(&WorkItem::Decode { seq: 1 }));
        assert_eq!(b.prefill_tokens(), 8, "guarded tick slices the prefill: {:?}", b.items);
        // the guard also withholds admissions once its token budget is spent
        w.phases.insert(3, (SeqPhase::Waiting, 64, 0));
        s.submit(3);
        let b = s.tick(w.lookup());
        assert_eq!(b.prefill_tokens(), 8);
        assert!(
            !b.items.iter().any(|i| matches!(i, WorkItem::Prefill { seq: 3, .. })),
            "admission must not start a prefill past the guard: {:?}",
            b.items
        );
        // without live decodes the guard is inert: full chunks again
        s.remove(1);
        w.phases.remove(&1);
        let b = s.tick(w.lookup());
        assert!(b.prefill_tokens() >= 128, "unguarded tick: {:?}", b.items);
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn fair_share_picks_least_indebted_tenant() {
        let mut s = Scheduler::new(ServeConfig { fair_share: true, max_running: 1, ..cfg() });
        let mut w = World { phases: HashMap::new() };
        for id in 1..=3u64 {
            w.phases.insert(id, (SeqPhase::Waiting, 32, 0));
        }
        s.submit(1);
        s.set_tenant(1, 7);
        s.submit(2);
        s.set_tenant(2, 7);
        s.submit(3);
        s.set_tenant(3, 8);
        // all accounts empty: FCFS tie-break admits 1 (tenant 7)
        let b = s.tick(w.lookup());
        assert!(b.items.contains(&WorkItem::Prefill { seq: 1, tokens: 32 }), "{:?}", b.items);
        assert_eq!(s.tenant_debt(7), 32);
        w.phases.remove(&1);
        s.on_finished(1);
        // tenant 7 now owes 32 tokens: tenant 8's waiter jumps 2
        let b = s.tick(w.lookup());
        assert!(
            b.items.contains(&WorkItem::Prefill { seq: 3, tokens: 32 }),
            "least-indebted tenant admits first: {:?}",
            b.items
        );
        assert!(s.waiting.contains(&2));
        assert_eq!(s.tenant_debt(8), 32);
    }

    /// Fair-share must not override priority: a strictly higher-priority
    /// waiter from the indebted tenant still admits first.
    #[test]
    fn fair_share_defers_to_priority() {
        let mut s = Scheduler::new(ServeConfig { fair_share: true, max_running: 1, ..cfg() });
        let mut w = World { phases: HashMap::new() };
        w.phases.insert(1, (SeqPhase::Waiting, 32, 0));
        w.phases.insert(2, (SeqPhase::Waiting, 32, 0));
        s.submit_prio(1, 5);
        s.set_tenant(1, 7);
        *s.tenant_debt.entry(7).or_insert(0) += 10_000; // deeply indebted
        s.submit_prio(2, 0);
        s.set_tenant(2, 8);
        let b = s.tick(w.lookup());
        assert!(
            b.items.contains(&WorkItem::Prefill { seq: 1, tokens: 32 }),
            "priority outranks tenant debt: {:?}",
            b.items
        );
    }

    #[test]
    fn remove_tears_down_waiting_and_running() {
        let mut s = Scheduler::new(cfg());
        let mut w = World { phases: HashMap::new() };
        // running sequence with blocks
        w.phases.insert(1, (SeqPhase::Waiting, 64, 0));
        s.submit_with_prompt(1, &[0u32; 64]);
        let b = s.tick(w.lookup());
        assert!(!b.items.is_empty());
        assert!(s.blocks.used() > 0);
        // plus one still waiting
        s.submit_with_prompt(2, &[1u32; 64]);
        s.remove(1);
        s.remove(2);
        assert!(s.running.is_empty());
        assert!(s.waiting.is_empty());
        assert_eq!(s.blocks.used(), 0, "cancelled sequences release every block");
        s.blocks.check_invariants().unwrap();
        // removing an unknown id is a no-op
        s.remove(99);
        s.blocks.check_invariants().unwrap();
    }

    fn cache_cfg() -> ServeConfig {
        ServeConfig { enable_prefix_cache: true, prefix_cache_blocks: 64, ..cfg() }
    }

    /// Drive one sequence through full prefill + registration, then
    /// finish it, leaving its prompt blocks in the cached pool.
    fn prefill_and_cache(
        s: &mut Scheduler,
        w: &mut World,
        id: u64,
        prompt: &[u32],
    ) {
        s.submit_with_prompt(id, prompt);
        w.phases.insert(id, (SeqPhase::Waiting, prompt.len(), 0));
        let mut done = 0;
        while done < prompt.len() {
            let b = s.tick(w.lookup());
            let take = b
                .items
                .iter()
                .find_map(|it| match it {
                    WorkItem::Prefill { seq, tokens } if *seq == id => Some(*tokens),
                    _ => None,
                })
                .expect("prefill scheduled");
            done += take;
            let ph = if done >= prompt.len() {
                SeqPhase::Decoding
            } else {
                SeqPhase::Prefilling { done }
            };
            w.phases.insert(id, (ph, prompt.len(), done));
            // engine-style registration at the block-aligned boundary
            let boundary = done.min(prompt.len() - 1) / s.cfg.block_size * s.cfg.block_size;
            s.register_prefix(id, boundary, true);
        }
        w.phases.remove(&id);
        s.on_finished(id);
    }

    #[test]
    fn admission_adopts_cached_prefix_and_skips_prefill() {
        let mut s = Scheduler::new(cache_cfg());
        let mut w = World { phases: HashMap::new() };
        let prompt: Vec<u32> = (0..300).map(|i| i as u32 % 50).collect();
        prefill_and_cache(&mut s, &mut w, 1, &prompt);
        assert!(s.blocks.cached() > 0, "prompt blocks parked in the pool");
        s.blocks.check_invariants().unwrap();

        // same prompt again: admission must adopt the cached chain and
        // schedule only the uncached remainder
        s.submit_with_prompt(2, &prompt);
        w.phases.insert(2, (SeqPhase::Waiting, prompt.len(), 0));
        let b = s.tick(w.lookup());
        assert_eq!(b.cache_hits.len(), 1);
        let (seq, cached, _hash) = b.cache_hits[0];
        assert_eq!(seq, 2);
        // deepest registered boundary: floor((300 - 1) / 16) * 16 = 288
        assert_eq!(cached, 288);
        assert_eq!(s.prefix.stats.hits, 1);
        assert_eq!(s.prefix.stats.saved_tokens, 288);
        assert!(
            b.items.contains(&WorkItem::Prefill { seq: 2, tokens: 12 }),
            "only the 12 uncached tokens are prefilled: {:?}",
            b.items
        );
        assert!(b.budget_used < prompt.len(), "cached tokens cost no budget");
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn different_prompt_misses() {
        let mut s = Scheduler::new(cache_cfg());
        let mut w = World { phases: HashMap::new() };
        let prompt: Vec<u32> = (0..300).map(|i| i as u32 % 50).collect();
        prefill_and_cache(&mut s, &mut w, 1, &prompt);
        let other: Vec<u32> = (0..300).map(|i| (i as u32 % 50) + 1).collect();
        s.submit_with_prompt(2, &other);
        w.phases.insert(2, (SeqPhase::Waiting, other.len(), 0));
        let b = s.tick(w.lookup());
        assert!(b.cache_hits.is_empty());
        assert_eq!(b.cache_misses, 1);
        assert_eq!(s.prefix.stats.misses, 2, "seq 1's cold admission also missed");
        assert!(b.items.contains(&WorkItem::Prefill { seq: 2, tokens: 128 }));
    }

    #[test]
    fn eviction_under_pressure_invalidates_entries() {
        // pool so small that new allocations must evict cached blocks
        let mut s = Scheduler::new(ServeConfig {
            num_blocks: 20, // 320 tokens
            ..cache_cfg()
        });
        let mut w = World { phases: HashMap::new() };
        let prompt: Vec<u32> = (0..300).map(|i| i as u32 % 50).collect();
        prefill_and_cache(&mut s, &mut w, 1, &prompt);
        let cached_before = s.blocks.cached();
        assert!(cached_before >= 18);
        // an unrelated large prompt forces eviction of the cached chain
        let other: Vec<u32> = (0..300).map(|i| (i as u32 % 50) + 1).collect();
        s.submit_with_prompt(2, &other);
        w.phases.insert(2, (SeqPhase::Waiting, other.len(), 0));
        let b = s.tick(w.lookup());
        assert!(b.items.iter().any(|i| matches!(i, WorkItem::Prefill { seq: 2, .. })));
        let invalidated = s.take_invalidated();
        assert!(!invalidated.is_empty(), "evicted blocks drop their index entries");
        assert!(s.prefix.stats.evictions > 0);
        s.blocks.check_invariants().unwrap();
    }

    #[test]
    fn prop_budget_and_block_invariants_hold() {
        check("scheduler invariants", 20, |rng| {
            let c = ServeConfig {
                block_size: 1 + rng.below(16),
                num_blocks: 8 + rng.below(64),
                max_running: 1 + rng.below(8),
                token_budget: 16 + rng.below(256),
                prefill_chunk: 1 + rng.below(128),
                queue_cap: 64,
                workers: 1,
                ..ServeConfig::default()
            };
            let budget = c.token_budget;
            let mut s = Scheduler::new(c);
            let mut phases: HashMap<u64, (SeqPhase, usize, usize)> = HashMap::new();
            let mut next_id = 0u64;
            for step in 0..60 {
                // random arrivals
                for _ in 0..rng.below(3) {
                    next_id += 1;
                    phases.insert(next_id, (SeqPhase::Waiting, 1 + rng.below(400), 0));
                    s.submit(next_id);
                }
                let batch = {
                    let ph = phases.clone();
                    s.tick(move |id| ph.get(&id).copied())
                };
                prop_assert!(
                    batch.budget_used <= budget,
                    "step {step}: budget {} > {budget}",
                    batch.budget_used
                );
                // at most one work item per sequence per tick
                let mut seen = std::collections::HashSet::new();
                for it in &batch.items {
                    let id = match it {
                        WorkItem::Prefill { seq, .. } | WorkItem::Decode { seq } => *seq,
                    };
                    prop_assert!(seen.insert(id), "step {step}: duplicate work for {id}");
                }
                if let Err(e) = s.blocks.check_invariants() {
                    return Err(format!("step {step}: {e}"));
                }
                // apply work
                for it in &batch.items {
                    match *it {
                        WorkItem::Prefill { seq, tokens } => {
                            let (ph, plen, tot) = phases[&seq];
                            let done = match ph {
                                SeqPhase::Waiting => 0,
                                SeqPhase::Prefilling { done } => done,
                                _ => continue,
                            };
                            let nd = done + tokens;
                            let nph = if nd >= plen { SeqPhase::Decoding } else { SeqPhase::Prefilling { done: nd } };
                            phases.insert(seq, (nph, plen, tot + tokens));
                        }
                        WorkItem::Decode { seq } => {
                            let (_, plen, tot) = phases[&seq];
                            // finish with probability ~1/8
                            if rng.below(8) == 0 {
                                phases.remove(&seq);
                                s.on_finished(seq);
                            } else {
                                phases.insert(seq, (SeqPhase::Decoding, plen, tot + 1));
                            }
                        }
                    }
                }
                for p in batch.preempted {
                    if let Some(e) = phases.get_mut(&p) {
                        *e = (SeqPhase::Waiting, e.1 + (e.2), 0);
                    }
                }
            }
            Ok(())
        });
    }
}
