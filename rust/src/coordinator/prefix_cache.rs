//! Automatic prefix caching: a hash-of-token-block index (vLLM-style).
//!
//! Prompts are chunked into full blocks of `block_size` tokens; block `j`
//! is identified by a *chain hash* folding block `j-1`'s hash with block
//! `j`'s token contents, so equal hashes mean equal whole prefixes (up to
//! 64-bit collisions), not just equal blocks.  The index maps chain
//! hashes to physical block ids in the [`super::BlockManager`]; a new
//! sequence whose prompt matches a cached chain adopts those blocks
//! (refcount sharing) and starts prefill at its first uncached token.
//!
//! Entries are registered by the engine as sequences fill prompt blocks.
//! Boundaries at which the engine also holds a backend state snapshot are
//! flagged *resumable*; only resumable boundaries can be admission
//! targets, because skipping prefill compute requires state to resume
//! from.  Entries die when their physical block is evicted from the
//! cached pool (the scheduler forwards [`super::BlockManager`] eviction
//! logs into [`PrefixIndex::forget_block`]).

use std::collections::HashMap;

/// Seed for the block-0 chain hash.
const CHAIN_SEED: u64 = 0x4B41_5343_4144_4531; // "KASCADE1"

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Chain hashes for every *full* block of `tokens`: `out[j]` covers
/// `tokens[..(j + 1) * block_size]`.
pub fn chain_hashes(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let n = tokens.len() / block_size;
    let mut out = Vec::with_capacity(n);
    let mut h = CHAIN_SEED;
    for j in 0..n {
        for &t in &tokens[j * block_size..(j + 1) * block_size] {
            h = mix(h ^ (t as u64).wrapping_add(0x9E37_79B9_7F4A_7C15));
        }
        out.push(h);
    }
    out
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    block: u32,
    /// the engine holds a state snapshot at this boundary
    resumable: bool,
}

/// Scheduler-local counters (asserted by the scheduler's unit tests).
/// The serving-surface source of truth is [`super::ServeMetrics`], whose
/// hit/saved counts the engine increments only when a snapshot resume
/// actually happens — the two can differ by design if a snapshot was
/// capped away between adoption and resume.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    /// admissions that adopted a cached prefix
    pub hits: u64,
    /// admissions that found no usable cached prefix
    pub misses: u64,
    /// prefill tokens skipped via adopted prefixes
    pub saved_tokens: u64,
    /// index entries dropped because their block was evicted
    pub evictions: u64,
}

#[derive(Debug, Default)]
pub struct PrefixIndex {
    entries: HashMap<u64, Entry>,
    /// reverse map for eviction invalidation (block -> chain hash)
    by_block: HashMap<u32, u64>,
    /// hashes forgotten since the last drain (engine prunes snapshots)
    invalidated: Vec<u64>,
    pub stats: PrefixStats,
}

/// Result of a prefix match at admission.  The match covers
/// `blocks.len() * block_size` prompt tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixMatch {
    /// physical blocks of the matched chain, in order
    pub blocks: Vec<u32>,
    /// chain hash at the resume boundary (keys the engine's snapshot)
    pub hash: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Register `block` under `hash`.  First registration wins: an
    /// existing live entry for the same content keeps its block (the
    /// duplicate block stays private to its sequence).  Returns whether
    /// `block` is now the indexed one.
    pub fn register(&mut self, hash: u64, block: u32) -> bool {
        if let Some(e) = self.entries.get(&hash) {
            return e.block == block;
        }
        self.entries.insert(hash, Entry { block, resumable: false });
        self.by_block.insert(block, hash);
        true
    }

    /// Flag `hash` as a resume boundary (the engine stored a snapshot).
    pub fn mark_resumable(&mut self, hash: u64) {
        if let Some(e) = self.entries.get_mut(&hash) {
            e.resumable = true;
        }
    }

    /// Un-flag a resume boundary (the engine dropped its snapshot, e.g.
    /// to bound snapshot memory); the blocks stay indexed and shareable
    /// through deeper resumable boundaries.
    pub fn unmark_resumable(&mut self, hash: u64) {
        if let Some(e) = self.entries.get_mut(&hash) {
            e.resumable = false;
        }
    }

    pub fn is_resumable(&self, hash: u64) -> bool {
        self.entries.get(&hash).map_or(false, |e| e.resumable)
    }

    /// Longest usable cached prefix for a prompt with chain hashes
    /// `hashes`, considering at most `limit` blocks (the caller caps at
    /// `(prompt_len - 1) / block_size` so at least one token is left to
    /// compute).  `alive` reports whether a block's content still exists
    /// (owned or cached in the block manager); dead entries found on the
    /// walk are dropped.  Returns the deepest *resumable* boundary.
    pub fn lookup<F: Fn(u32) -> bool>(
        &mut self,
        hashes: &[u64],
        limit: usize,
        alive: F,
    ) -> Option<PrefixMatch> {
        let mut chain = Vec::new();
        let mut best: Option<(usize, u64)> = None;
        for (j, &h) in hashes.iter().take(limit).enumerate() {
            let e = match self.entries.get(&h) {
                Some(e) => *e,
                None => break,
            };
            if !alive(e.block) {
                self.forget_hash(h);
                break;
            }
            chain.push(e.block);
            if e.resumable {
                best = Some((j + 1, h));
            }
        }
        best.map(|(depth, hash)| {
            chain.truncate(depth);
            PrefixMatch { blocks: chain, hash }
        })
    }

    /// Drop the entry for an evicted block; returns its hash so the
    /// engine can prune the matching snapshot.
    pub fn forget_block(&mut self, block: u32) -> Option<u64> {
        let h = self.by_block.get(&block).copied()?;
        // guard against the block having been re-registered under a new
        // hash after eviction + reallocation
        if self.entries.get(&h).map_or(false, |e| e.block == block) {
            self.forget_hash(h);
            self.stats.evictions += 1;
            Some(h)
        } else {
            self.by_block.remove(&block);
            None
        }
    }

    fn forget_hash(&mut self, h: u64) {
        if let Some(e) = self.entries.remove(&h) {
            self.by_block.remove(&e.block);
            self.invalidated.push(h);
        }
    }

    /// Drain hashes invalidated since the last call.
    pub fn drain_invalidated(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.invalidated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hashes_are_prefix_sensitive() {
        let a: Vec<u32> = (0..64).collect();
        let mut b = a.clone();
        let ha = chain_hashes(&a, 16);
        assert_eq!(ha.len(), 4);
        // equal prefixes, equal hashes
        assert_eq!(chain_hashes(&b, 16), ha);
        // perturbing block 1 changes hashes 1.. but not hash 0
        b[17] ^= 1;
        let hb = chain_hashes(&b, 16);
        assert_eq!(hb[0], ha[0]);
        assert_ne!(hb[1], ha[1]);
        assert_ne!(hb[2], ha[2]);
        // partial trailing block contributes nothing
        assert_eq!(chain_hashes(&a[..63], 16).len(), 3);
    }

    #[test]
    fn register_lookup_roundtrip() {
        let toks: Vec<u32> = (0..64).collect();
        let hs = chain_hashes(&toks, 16);
        let mut idx = PrefixIndex::new();
        for (j, &h) in hs.iter().enumerate() {
            assert!(idx.register(h, j as u32));
        }
        idx.mark_resumable(hs[2]);
        // limit 4: deepest resumable boundary is block 3 (hash index 2)
        let m = idx.lookup(&hs, 4, |_| true).unwrap();
        assert_eq!(m.blocks, vec![0, 1, 2]);
        assert_eq!(m.hash, hs[2]);
        // limit 2: no resumable boundary within reach
        assert!(idx.lookup(&hs, 2, |_| true).is_none());
        // a dead block truncates the walk and drops the entry
        let m = idx.lookup(&hs, 4, |b| b != 1);
        assert!(m.is_none(), "resumable boundary beyond the dead block");
        assert_eq!(idx.drain_invalidated(), vec![hs[1]]);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn first_registration_wins() {
        let mut idx = PrefixIndex::new();
        assert!(idx.register(42, 7));
        assert!(!idx.register(42, 9), "duplicate content keeps the first block");
        idx.mark_resumable(42);
        let m = idx.lookup(&[42], 1, |_| true).unwrap();
        assert_eq!(m.blocks, vec![7]);
    }

    #[test]
    fn forget_block_invalidates_snapshot_hash() {
        let mut idx = PrefixIndex::new();
        idx.register(1, 10);
        idx.register(2, 11);
        assert_eq!(idx.forget_block(10), Some(1));
        assert_eq!(idx.forget_block(10), None, "already gone");
        assert_eq!(idx.stats.evictions, 1);
        assert!(idx.lookup(&[1], 1, |_| true).is_none());
    }
}
