//! Serving metrics: TTFT / TPOT / throughput / KV utilization /
//! session outcomes (cancellations, deadline misses, streamed TTFT).

use crate::stats::{LatencyHist, Welford};
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Debug)]
pub struct ServeMetrics {
    pub started: Instant,
    pub ttft_us: LatencyHist,
    pub tpot_us: Welford,
    /// per-token inter-arrival latency samples (same values `tpot_us`
    /// averages, retained for exact p50/p95/p99 — the SLO gate's TPOT)
    pub tpot_hist: LatencyHist,
    /// prefill tokens scheduled per engine tick — the quantity
    /// `ServeConfig::decode_guard_prefill_tokens` bounds; `max()` over a
    /// run verifies the guard held
    pub prefill_tokens_per_tick: Welford,
    pub tokens_out: u64,
    pub prompts_in: u64,
    pub requests_done: u64,
    pub preemptions: u64,
    pub kv_util: Welford,
    pub batch_size: Welford,
    /// admissions that adopted a cached prompt prefix
    pub prefix_hits: u64,
    /// admissions that found no usable cached prefix (cache enabled)
    pub prefix_misses: u64,
    /// prefill tokens skipped by resuming from prefix-cache snapshots
    pub saved_prefill_tokens: u64,
    /// refcount-0 blocks parked in the prefix-cache pool (per tick)
    pub kv_cached: Welford,
    /// decode step-batch sizes, one sample per batched forward pass
    /// (log-bucketed histogram + exact percentiles)
    pub decode_batch: LatencyHist,
    /// decode tokens produced (batched + sequential decode execution)
    pub decode_tokens: u64,
    /// wall time spent inside decode execution, microseconds
    pub decode_time_us: f64,
    /// KV bytes resident across live sequences (sampled per tick from
    /// backends that track storage — storage-mode aware, so int8 blocks
    /// report their true size)
    pub kv_bytes_resident: Welford,
    /// high-water mark of resident KV bytes
    pub peak_kv_bytes: usize,
    /// quantized KV value rows read through the dequantizing attend path
    /// (accumulated from finished sequences; 0 in pure-f32 serving)
    pub dequant_rows: u64,
    /// KV tiles promoted hot (tiered KV; planned prefetch + demand)
    pub tiles_promoted: u64,
    /// KV tiles demoted out of the hot arena (tiered KV)
    pub tiles_demoted: u64,
    /// tiles a policy-phase `ensure` found already hot (tiered KV —
    /// the tick-boundary prefetch worked)
    pub prefetch_hits: u64,
    /// tiles a policy-phase `ensure` had to promote on demand (tiered
    /// KV — the hint missed or arrived late)
    pub prefetch_misses: u64,
    /// wall time of each full engine tick (sweep + schedule + execute +
    /// retire), microseconds
    pub tick_us: Welford,
    /// worker threads serving the parallel decode tick (1 = serial)
    pub threads: usize,
    /// requests torn down by a client `cancel()`
    pub cancelled: u64,
    /// requests torn down by deadline expiry
    pub deadline_missed: u64,
    /// TTFT measured at the *handle* (submit -> first `Token` event
    /// observed by the client, queueing and delivery included) — the
    /// latency a user actually sees, vs. the engine-side `ttft_us`.
    /// Shared with every `RequestHandle` the engine/server creates; in a
    /// multi-worker `Server` all workers share one collector.
    pub streamed_ttft_us: Arc<Mutex<LatencyHist>>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            ttft_us: LatencyHist::new(),
            tpot_us: Welford::new(),
            tpot_hist: LatencyHist::new(),
            prefill_tokens_per_tick: Welford::new(),
            tokens_out: 0,
            prompts_in: 0,
            requests_done: 0,
            preemptions: 0,
            kv_util: Welford::new(),
            batch_size: Welford::new(),
            prefix_hits: 0,
            prefix_misses: 0,
            saved_prefill_tokens: 0,
            kv_cached: Welford::new(),
            decode_batch: LatencyHist::new(),
            decode_tokens: 0,
            decode_time_us: 0.0,
            kv_bytes_resident: Welford::new(),
            peak_kv_bytes: 0,
            dequant_rows: 0,
            tiles_promoted: 0,
            tiles_demoted: 0,
            prefetch_hits: 0,
            prefetch_misses: 0,
            tick_us: Welford::new(),
            threads: 1,
            cancelled: 0,
            deadline_missed: 0,
            streamed_ttft_us: Arc::new(Mutex::new(LatencyHist::new())),
        }
    }

    /// Fold every counter / accumulator / histogram of `other` into
    /// `self` — everything EXCEPT the shared `streamed_ttft_us`
    /// collector, which needs identity-aware handling (see [`merge`]).
    ///
    /// [`merge`]: ServeMetrics::merge
    pub(crate) fn fold_counters(&mut self, other: &ServeMetrics) {
        self.started = self.started.min(other.started);
        self.ttft_us.merge(&other.ttft_us);
        self.tpot_us.merge(&other.tpot_us);
        self.tpot_hist.merge(&other.tpot_hist);
        self.prefill_tokens_per_tick.merge(&other.prefill_tokens_per_tick);
        self.tokens_out += other.tokens_out;
        self.prompts_in += other.prompts_in;
        self.requests_done += other.requests_done;
        self.preemptions += other.preemptions;
        self.kv_util.merge(&other.kv_util);
        self.batch_size.merge(&other.batch_size);
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.saved_prefill_tokens += other.saved_prefill_tokens;
        self.kv_cached.merge(&other.kv_cached);
        self.decode_batch.merge(&other.decode_batch);
        self.decode_tokens += other.decode_tokens;
        self.decode_time_us += other.decode_time_us;
        self.kv_bytes_resident.merge(&other.kv_bytes_resident);
        // workers never share a block pool, so the fleet high-water mark
        // is bounded by (and reported as) the sum of per-worker peaks
        self.peak_kv_bytes += other.peak_kv_bytes;
        self.dequant_rows += other.dequant_rows;
        self.tiles_promoted += other.tiles_promoted;
        self.tiles_demoted += other.tiles_demoted;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_misses += other.prefetch_misses;
        self.tick_us.merge(&other.tick_us);
        self.threads += other.threads;
        self.cancelled += other.cancelled;
        self.deadline_missed += other.deadline_missed;
    }

    /// Aggregate per-worker / per-replica metrics into one coherent
    /// view: counters sum, Welford accumulators and histograms fold
    /// exactly ([`Welford::merge`], [`LatencyHist::merge`]).  Shared
    /// streamed-TTFT collectors are deduplicated by `Arc` identity — a
    /// `Server`'s workers all report the one collector their handles
    /// feed, so summing it once per worker would multiply every sample
    /// by the worker count.
    pub fn merge(parts: &[ServeMetrics]) -> ServeMetrics {
        let mut out = ServeMetrics::new();
        out.threads = 0;
        let mut seen: Vec<*const Mutex<LatencyHist>> = Vec::new();
        for m in parts {
            out.fold_counters(m);
            let collector = Arc::as_ptr(&m.streamed_ttft_us);
            if seen.contains(&collector) {
                continue;
            }
            seen.push(collector);
            if let (Ok(src), Ok(mut dst)) =
                (m.streamed_ttft_us.lock(), out.streamed_ttft_us.lock())
            {
                dst.merge(&src);
            }
        }
        out
    }

    /// Handle-observed TTFT percentile (microseconds).
    pub fn streamed_ttft_percentile(&self, p: f64) -> f64 {
        self.streamed_ttft_us.lock().map(|h| h.percentile(p)).unwrap_or(0.0)
    }

    /// Engine-observed TTFT percentile (microseconds).
    pub fn ttft_percentile(&self, p: f64) -> f64 {
        self.ttft_us.percentile(p)
    }

    /// TPOT percentile (microseconds per output token).
    pub fn tpot_percentile(&self, p: f64) -> f64 {
        self.tpot_hist.percentile(p)
    }

    /// Record one tick's total resident KV bytes.
    pub fn sample_kv_bytes(&mut self, bytes: usize) {
        self.kv_bytes_resident.add(bytes as f64);
        self.peak_kv_bytes = self.peak_kv_bytes.max(bytes);
    }

    /// Decode throughput over time actually spent decoding (excludes
    /// prefill and scheduling work — the paper's decode-attention metric).
    pub fn decode_tok_s(&self) -> f64 {
        if self.decode_time_us <= 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / (self.decode_time_us / 1e6)
        }
    }

    /// Fold one maintenance round's tier counters in
    /// ([`crate::tilestore::TierStats`], drained per sequence per tick).
    pub fn add_tier_stats(&mut self, s: &crate::tilestore::TierStats) {
        self.tiles_promoted += s.tiles_promoted;
        self.tiles_demoted += s.tiles_demoted;
        self.prefetch_hits += s.prefetch_hits;
        self.prefetch_misses += s.prefetch_misses;
    }

    /// Fraction of policy-phase tile needs the tick-boundary prefetch
    /// had already staged hot (1.0 = every needed tile was resident;
    /// 0 when tiering never ran).
    pub fn prefetch_hit_rate(&self) -> f64 {
        let total = self.prefetch_hits + self.prefetch_misses;
        if total == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / total as f64
        }
    }

    /// Prefix-cache hit rate over admissions (0 when the cache saw none).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} throughput={:.1} tok/s  \
             ttft p50={:.1}ms p95={:.1}ms p99={:.1}ms  \
             tpot mean={:.2}ms p95={:.2}ms p99={:.2}ms  \
             batch mean={:.1}  kv_util mean={:.0}%  preemptions={}  \
             prefix hits={} misses={} saved={} tok  kv_cached mean={:.0}  \
             decode_batch p50={:.0} max={:.0}  decode={:.1} tok/s  \
             kv_bytes peak={}  dequant_rows={}  \
             tiles promoted={} demoted={} prefetch hits={} misses={}  \
             tick mean={:.0}us max={:.0}us threads={}  \
             cancelled={} deadline_miss={} streamed_ttft p50={:.1}ms",
            self.requests_done,
            self.tokens_out,
            self.throughput_tok_s(),
            self.ttft_us.percentile(50.0) / 1e3,
            self.ttft_us.percentile(95.0) / 1e3,
            self.ttft_us.percentile(99.0) / 1e3,
            self.tpot_us.mean() / 1e3,
            self.tpot_hist.percentile(95.0) / 1e3,
            self.tpot_hist.percentile(99.0) / 1e3,
            self.batch_size.mean(),
            self.kv_util.mean() * 100.0,
            self.preemptions,
            self.prefix_hits,
            self.prefix_misses,
            self.saved_prefill_tokens,
            self.kv_cached.mean(),
            self.decode_batch.percentile(50.0),
            self.decode_batch.percentile(100.0),
            self.decode_tok_s(),
            self.peak_kv_bytes,
            self.dequant_rows,
            self.tiles_promoted,
            self.tiles_demoted,
            self.prefetch_hits,
            self.prefetch_misses,
            self.tick_us.mean(),
            self.tick_us.max(),
            self.threads,
            self.cancelled,
            self.deadline_missed,
            self.streamed_ttft_percentile(50.0) / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_folds_counters_and_dedups_shared_streamed_collector() {
        let mut a = ServeMetrics::new();
        let mut b = ServeMetrics::new();
        // workers of one Server share the streamed-TTFT collector
        b.streamed_ttft_us = a.streamed_ttft_us.clone();
        let mut c = ServeMetrics::new(); // a second replica: own collector
        a.tokens_out = 10;
        b.tokens_out = 5;
        c.tokens_out = 1;
        a.prefix_hits = 3;
        c.prefix_hits = 4;
        a.threads = 2;
        b.threads = 2;
        c.threads = 1;
        a.peak_kv_bytes = 100;
        b.peak_kv_bytes = 50;
        for us in [100.0, 200.0] {
            a.ttft_us.add_us(us);
            b.tpot_hist.add_us(us);
            c.ttft_us.add_us(us * 10.0);
        }
        a.tick_us.add(10.0);
        b.tick_us.add(30.0);
        a.streamed_ttft_us.lock().unwrap().add_us(1000.0);
        c.streamed_ttft_us.lock().unwrap().add_us(3000.0);
        let m = ServeMetrics::merge(&[a, b, c]);
        assert_eq!(m.tokens_out, 16);
        assert_eq!(m.prefix_hits, 7);
        assert_eq!(m.threads, 5);
        assert_eq!(m.peak_kv_bytes, 150);
        assert_eq!(m.ttft_us.count(), 4);
        assert_eq!(m.tpot_hist.count(), 2);
        assert_eq!(m.tick_us.count(), 2);
        assert!((m.tick_us.mean() - 20.0).abs() < 1e-9);
        // the shared collector folds ONCE: 2 samples, not 3
        assert_eq!(m.streamed_ttft_us.lock().unwrap().count(), 2);
        assert!((m.streamed_ttft_percentile(100.0) - 3000.0).abs() < 1e-9);
        // empty merge is a well-formed zero view
        let z = ServeMetrics::merge(&[]);
        assert_eq!(z.threads, 0);
        assert_eq!(z.tokens_out, 0);
    }

    #[test]
    fn report_formats() {
        let mut m = ServeMetrics::new();
        m.ttft_us.add_us(1500.0);
        m.tpot_us.add(800.0);
        m.tokens_out = 10;
        m.requests_done = 1;
        m.cancelled = 2;
        m.deadline_missed = 1;
        m.streamed_ttft_us.lock().unwrap().add_us(2000.0);
        m.tick_us.add(123.0);
        m.threads = 4;
        m.add_tier_stats(&crate::tilestore::TierStats {
            tiles_promoted: 5,
            tiles_demoted: 3,
            prefetch_hits: 9,
            prefetch_misses: 1,
        });
        for us in [500.0, 800.0, 900.0] {
            m.tpot_hist.add_us(us);
        }
        assert!((m.tpot_percentile(50.0) - 800.0).abs() < 1e-9);
        assert!((m.ttft_percentile(50.0) - 1500.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("threads=4"));
        assert!(r.contains("tokens_out=10"));
        assert!(r.contains("cancelled=2"));
        assert!(r.contains("deadline_miss=1"));
        assert!(r.contains("tiles promoted=5 demoted=3"));
        assert!(r.contains("prefetch hits=9 misses=1"));
        assert!((m.prefetch_hit_rate() - 0.9).abs() < 1e-12);
        assert!((m.streamed_ttft_percentile(50.0) - 2000.0).abs() < 1e-9);
    }
}
