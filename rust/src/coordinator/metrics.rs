//! Serving metrics: TTFT / TPOT / throughput / KV utilization.

use crate::stats::{LatencyHist, Welford};
use std::time::Instant;

#[derive(Debug)]
pub struct ServeMetrics {
    pub started: Instant,
    pub ttft_us: LatencyHist,
    pub tpot_us: Welford,
    pub tokens_out: u64,
    pub prompts_in: u64,
    pub requests_done: u64,
    pub preemptions: u64,
    pub kv_util: Welford,
    pub batch_size: Welford,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            ttft_us: LatencyHist::new(),
            tpot_us: Welford::new(),
            tokens_out: 0,
            prompts_in: 0,
            requests_done: 0,
            preemptions: 0,
            kv_util: Welford::new(),
            batch_size: Welford::new(),
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} throughput={:.1} tok/s  \
             ttft p50={:.1}ms p99={:.1}ms  tpot mean={:.2}ms  \
             batch mean={:.1}  kv_util mean={:.0}%  preemptions={}",
            self.requests_done,
            self.tokens_out,
            self.throughput_tok_s(),
            self.ttft_us.percentile(50.0) / 1e3,
            self.ttft_us.percentile(99.0) / 1e3,
            self.tpot_us.mean() / 1e3,
            self.batch_size.mean(),
            self.kv_util.mean() * 100.0,
            self.preemptions,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_formats() {
        let mut m = ServeMetrics::new();
        m.ttft_us.add_us(1500.0);
        m.tpot_us.add(800.0);
        m.tokens_out = 10;
        m.requests_done = 1;
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("tokens_out=10"));
    }
}
