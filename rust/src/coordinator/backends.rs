//! Concrete [`SeqBackend`]s: the native SynthLM engine (policy-driven) and
//! the PJRT artifact path (plan-driven).

use super::sequence::{BatchParts, KvStats, SeqBackend};
use crate::config::KvDtype;
use crate::kascade::KascadePlan;
use crate::model::{Model, SeqState};
use crate::runtime::{PjrtModel, PjrtSeqState};
use crate::sparse::SparsePolicy;
use crate::tilestore::{SharedTileStore, TierParams, TierStats};
use std::sync::Arc;

/// Native engine backend: SynthLM forward on the CPU attention engine with
/// any [`SparsePolicy`].
pub struct NativeBackend {
    pub model: Arc<Model>,
    pub st: SeqState,
    pub policy: Box<dyn SparsePolicy>,
}

impl NativeBackend {
    pub fn new(model: Arc<Model>, cap: usize, policy: Box<dyn SparsePolicy>) -> Self {
        Self::with_dtype(model, cap, policy, KvDtype::F32)
    }

    /// Backend with an explicit KV storage precision
    /// ([`crate::config::ServeConfig::kv_dtype`]).  Int8 states store
    /// completed KV tiles quantized; sparse policies score over them
    /// fused, and only attended value rows dequantize.
    pub fn with_dtype(
        model: Arc<Model>,
        cap: usize,
        policy: Box<dyn SparsePolicy>,
        dtype: KvDtype,
    ) -> Self {
        let st = model.new_state_with_dtype(cap, dtype);
        Self { model, st, policy }
    }

    /// Backend with tiered int8 KV storage (`docs/kv-tiers.md`): layers
    /// the policy scans in full stay flat int8; the rest run under
    /// `tiers`' hot-tile budget against the shared spill `store`.
    pub fn with_tiers(
        model: Arc<Model>,
        cap: usize,
        policy: Box<dyn SparsePolicy>,
        tiers: TierParams,
        store: &SharedTileStore,
    ) -> Self {
        let st = model.new_state_tiered(cap, policy.as_ref(), tiers, store);
        Self { model, st, policy }
    }
}

impl SeqBackend for NativeBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        let (logits, _) = self.model.prefill(tokens, &mut self.st, self.policy.as_mut(), None);
        Some(logits)
    }

    fn decode(&mut self, token: u32) -> Vec<f32> {
        self.model.decode_step(token, &mut self.st, self.policy.as_mut())
    }

    /// Native sequences are step-batchable: the engine groups them by
    /// shared model and amortizes weight reads across the tick's decodes.
    fn batch_parts(&mut self) -> Option<BatchParts<'_>> {
        Some(BatchParts {
            model: &self.model,
            st: &mut self.st,
            policy: self.policy.as_mut(),
        })
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(KvStats {
            bytes: self.model.kv_bytes(&self.st),
            dequant_rows: self.st.cost.dequant_rows,
        })
    }

    /// `(page_size, completed tiles)` across this sequence's tiered
    /// layers; `None` when no layer runs tiered (flat or f32 states).
    fn tile_geometry(&self) -> Option<(usize, usize)> {
        let c = self.st.caches.iter().find(|c| c.is_tiered())?;
        Some((c.page_size(), c.len / c.page_size()))
    }

    /// Apply one tick-boundary tile plan to every tiered layer and drain
    /// the accumulated tier counters (planned promotions plus any
    /// policy-phase demand promotions since the last drain).
    fn apply_tile_plan(&mut self, promote: &[u32], demote: &[u32]) -> TierStats {
        let mut stats = TierStats::default();
        for c in &mut self.st.caches {
            if !c.is_tiered() {
                continue;
            }
            if let Err(e) = c.apply_tile_plan(promote, demote) {
                // spill-store corruption at the tick boundary has no
                // recovery path; the error is typed (TileStoreError)
                // and exercised at the store layer
                panic!("tiered KV tile plan failed: {e}");
            }
            stats.merge(&c.take_tier_stats());
        }
        stats
    }

    /// Prefix-cache snapshot: clone the KV state truncated to the first
    /// `tokens` positions.  The policy is forked *fresh* — Top-k index
    /// state is per-sequence and must not leak through shared snapshots
    /// (the resumed sequence's anchor layers rebuild their own).
    /// Cloning preserves the KV storage mode, and a block-aligned
    /// boundary (the only kind the engine snapshots) lands on a
    /// quantization-tile edge, so shared int8 tiles survive the fork
    /// byte-for-byte — no re-quantization.
    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        if tokens > self.st.pos {
            return None;
        }
        let policy = self.policy.fork_fresh()?;
        let mut st = self.st.clone();
        for c in &mut st.caches {
            c.truncate(tokens);
        }
        st.pos = tokens;
        st.cost = Default::default();
        Some(Box::new(NativeBackend { model: self.model.clone(), st, policy }))
    }
}

/// PJRT backend: executes the AOT HLO artifacts.  The prompt is buffered
/// and prefilled in one shot on the final chunk (the artifacts are
/// full-prompt-bucket ops; chunked prefill is a native-path feature).
pub struct PjrtBackend {
    pub model: Arc<PjrtModel>,
    pub st: PjrtSeqState,
    pub plan: Option<Arc<KascadePlan>>,
    buffered: Vec<u32>,
}

impl PjrtBackend {
    pub fn new(model: Arc<PjrtModel>, plan: Option<Arc<KascadePlan>>) -> Self {
        let st = model.new_state();
        Self { model, st, plan, buffered: Vec::new() }
    }
}

impl SeqBackend for PjrtBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], last: bool) -> Option<Vec<f32>> {
        self.buffered.extend_from_slice(tokens);
        if !last {
            return None;
        }
        let logits = self
            .model
            .prefill(&self.buffered, &mut self.st, self.plan.as_deref())
            // analyze: allow(panic-path) — PJRT artifact mismatch is a startup config error
            .expect("pjrt prefill");
        Some(logits)
    }

    fn decode(&mut self, token: u32) -> Vec<f32> {
        self.model
            .decode_step(token, &mut self.st, self.plan.as_deref())
            // analyze: allow(panic-path) — PJRT artifact mismatch is a startup config error
            .expect("pjrt decode")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SynthSpec;
    use crate::sparse::DensePolicy;

    #[test]
    fn native_backend_runs_retrieval_task() {
        let mut spec = SynthSpec::eval_base(11);
        spec.cfg.n_layers = 4;
        spec.block_starts = vec![1];
        let model = Arc::new(spec.build());
        let lay = spec.vocab_layout();
        let mut b = NativeBackend::new(model, 512, Box::new(DensePolicy));
        let mut toks = vec![crate::model::VocabLayout::BOS];
        for f in 0..60 {
            toks.push(lay.filler_tok(f));
        }
        toks[30] = lay.pair_tok(4, 9);
        toks.push(crate::model::VocabLayout::QUERY);
        toks.push(lay.key_tok(4));
        // chunked prefill through the trait
        let n = toks.len();
        assert!(b.prefill_chunk(&toks[..32], false).is_some()); // native returns logits anyway
        let logits = b.prefill_chunk(&toks[32..n], true).unwrap();
        assert_eq!(crate::tensor::argmax(&logits) as u32, lay.value_tok(9));
    }
}
