//! Sequence lifecycle and the inference-backend abstraction.  The
//! client-facing request/event types live in [`super::api`].

use super::api::{Event, Request, Session};
use super::blocks::BlockManager;
use crate::model::{Model, SeqState};
use crate::sparse::SparsePolicy;
use crate::tilestore::TierStats;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Sequence phase in the continuous batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    Waiting,
    /// `done` prompt tokens already prefilled.
    Prefilling { done: usize },
    Decoding,
    Finished,
}

/// What actually runs a sequence's forward passes.  Implemented by the
/// native engine (SynthLM + SparsePolicy) and the PJRT artifact path.
///
/// Deliberately NOT `Send`: backends are created inside their worker
/// thread by the (Send) [`crate::server::BackendFactory`] and never cross
/// threads — which lets the Rc-based PJRT client implement it.
pub trait SeqBackend {
    /// Prefill a chunk of prompt tokens; `last` marks the final chunk, for
    /// which last-token logits must be returned.
    fn prefill_chunk(&mut self, tokens: &[u32], last: bool) -> Option<Vec<f32>>;
    /// One decode step; returns next-token logits.
    fn decode(&mut self, token: u32) -> Vec<f32>;
    /// Fork a copy of this backend holding exactly the first `tokens`
    /// tokens of sequence state (`tokens <= ` what has been consumed so
    /// far).  Powers prefix-cache snapshots: a forked copy is stored by
    /// the engine and re-forked to fast-forward later sequences past
    /// their cached prompt prefix.  `None` (the default) disables
    /// prefix-cache compute reuse for this backend.
    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        let _ = tokens;
        None
    }
    /// Exclusive access to the pieces the step-batched decode engine
    /// needs ([`crate::model::Model::decode_batch`]): the shared model
    /// plus this sequence's state and policy.  `None` (the default) means
    /// the backend only supports sequential decode — the engine falls
    /// back to [`SeqBackend::decode`] for it (PJRT, test doubles).
    /// Implementations should be stable between mutations: a `Some`
    /// answer is expected to stay `Some` (with the same model) for
    /// repeated calls within one engine tick.
    fn batch_parts(&mut self) -> Option<BatchParts<'_>> {
        None
    }
    /// KV-storage accounting for this sequence, if the backend tracks it
    /// (`None` for PJRT and test doubles).  The engine samples these per
    /// tick into [`crate::coordinator::ServeMetrics`]: resident KV bytes
    /// (storage-mode aware — int8 blocks count their true size) and the
    /// cumulative count of quantized rows read through the dequantizing
    /// attend path.
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
    /// Tile geometry of this backend's tiered KV caches — `(page_size,
    /// completed tiles)` — or `None` when the backend runs no tiered
    /// storage (flat caches, PJRT, test doubles).  `None` disables tier
    /// maintenance for the sequence.
    fn tile_geometry(&self) -> Option<(usize, usize)> {
        None
    }
    /// Apply a tick-boundary tile plan (`docs/kv-tiers.md`) to every
    /// tiered cache and drain the tier counters accumulated since the
    /// last call (planned work + demand promotions + prefetch hit/miss
    /// tallies).  Default: no-op with empty stats.
    fn apply_tile_plan(&mut self, promote: &[u32], demote: &[u32]) -> TierStats {
        let _ = (promote, demote);
        TierStats::default()
    }
}

/// KV-storage accounting snapshot (see [`SeqBackend::kv_stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvStats {
    /// Bytes of KV storage currently resident for the sequence.
    pub bytes: usize,
    /// Cumulative quantized value rows dequantized on attend.
    pub dequant_rows: u64,
}

/// Borrowed view into a batch-capable backend (see
/// [`SeqBackend::batch_parts`]).  The engine groups sequences whose
/// `model` Arcs are identical and runs them through one
/// [`crate::model::Model::decode_batch`] call per tick — staged in the
/// engine's persistent [`crate::model::BatchScratch`] and, with
/// `num_threads > 1`, sharded across the engine's worker pool (the
/// per-sequence [`crate::attention::AttnScratch`] inside `st` carries
/// the policy's selection between the policy and attention phases).
pub struct BatchParts<'a> {
    pub model: &'a Arc<Model>,
    pub st: &'a mut SeqState,
    pub policy: &'a mut dyn SparsePolicy,
}

/// A live sequence owned by a worker.
pub struct Sequence {
    pub req: Request,
    pub phase: SeqPhase,
    pub backend: Box<dyn SeqBackend>,
    pub emitted: Vec<u32>,
    /// logits pending token selection (set after prefill completes)
    pub pending_logits: Option<Vec<f32>>,
    pub arrived: Instant,
    /// absolute deadline derived from `req.deadline_ms` at submission
    pub deadline: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// number of times this sequence was preempted (blocks reclaimed)
    pub preemptions: usize,
    /// prompt length of the original request — preemption folds emitted
    /// tokens into `req.prompt` for recompute, and everything past this
    /// mark is response, not prompt
    pub orig_prompt_len: usize,
    /// prompt tokens skipped via prefix-cache resume (lifetime total)
    pub cached_prefix: usize,
    /// the event/cancellation channel back to the client's handle
    session: Session,
    /// `Event::Started` already delivered (survives preemption — a
    /// re-admission is not a second start)
    started_sent: bool,
    /// scratch buffers for tick-boundary tier maintenance (hint /
    /// promote / demote tile ids) — retained so steady-state ticks
    /// reuse capacity instead of allocating
    tier_hint: Vec<u32>,
    tier_promote: Vec<u32>,
    tier_demote: Vec<u32>,
}

impl Sequence {
    pub fn new(req: Request, session: Session, backend: Box<dyn SeqBackend>) -> Self {
        let orig_prompt_len = req.prompt.len();
        // the latency/deadline epoch is the CLIENT's submission instant
        // (the session's creation), not when a busy worker dequeued the
        // request — queueing time counts against the budget
        let arrived = session.created();
        let deadline = req
            .deadline_ms
            .map(|ms| arrived + Duration::from_secs_f64(ms.max(0.0) / 1e3));
        Self {
            req,
            phase: SeqPhase::Waiting,
            backend,
            emitted: Vec::new(),
            pending_logits: None,
            arrived,
            deadline,
            first_token_at: None,
            finished_at: None,
            preemptions: 0,
            orig_prompt_len,
            cached_prefix: 0,
            session,
            started_sent: false,
            tier_hint: Vec::new(),
            tier_promote: Vec::new(),
            tier_demote: Vec::new(),
        }
    }

    /// Tick-boundary KV-tier maintenance (`docs/kv-tiers.md`): collect
    /// the policy's `needed_tiles` hint, fold it through the
    /// [`BlockManager`] ledger into a promote/demote plan, apply the
    /// plan to the backend's tiered caches, and return the drained tier
    /// counters.  `None` when the backend runs no tiered storage.  The
    /// engine runs this between ticks — never inside the parallel
    /// decode pass — so promotion staging cannot perturb the
    /// bitwise-deterministic tick.
    pub fn tier_maintenance(
        &mut self,
        seq_id: u64,
        blocks: &mut BlockManager,
    ) -> Option<TierStats> {
        let (page_size, n_tiles) = self.backend.tile_geometry()?;
        let hinted = match self.backend.batch_parts() {
            Some(parts) => parts.policy.needed_tiles(page_size, &mut self.tier_hint),
            None => false,
        };
        if hinted {
            blocks.plan_tiles(
                seq_id,
                &self.tier_hint,
                n_tiles,
                &mut self.tier_promote,
                &mut self.tier_demote,
            );
        } else {
            self.tier_promote.clear();
            self.tier_demote.clear();
        }
        Some(self.backend.apply_tile_plan(&self.tier_promote, &self.tier_demote))
    }

    /// Deliver an event to the client's handle.
    pub fn send_event(&self, ev: Event) {
        self.session.send(ev);
    }

    /// Whether the client requested cancellation via its handle.
    pub fn cancel_requested(&self) -> bool {
        self.session.cancelled()
    }

    /// Whether the request's deadline has expired as of `now`.
    pub fn past_deadline(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }

    fn mark_started(&mut self) {
        if !self.started_sent {
            self.started_sent = true;
            self.session.send(Event::Started);
        }
    }

    /// Every response token emitted so far, including tokens folded into
    /// the prompt by preemption.
    pub fn response_tokens(&self) -> Vec<u32> {
        let mut out = self.req.prompt[self.orig_prompt_len..].to_vec();
        out.extend_from_slice(&self.emitted);
        out
    }

    /// Total response tokens emitted (folded + live).
    pub fn emitted_total(&self) -> usize {
        self.req.prompt.len() - self.orig_prompt_len + self.emitted.len()
    }

    /// Fast-forward a waiting sequence past a cached prompt prefix: the
    /// engine installs a backend snapshot already holding `done` tokens
    /// and prefill resumes at the first uncached token.
    pub fn fast_forward(&mut self, done: usize, backend: Box<dyn SeqBackend>) {
        debug_assert_eq!(self.phase, SeqPhase::Waiting);
        debug_assert!(done < self.req.prompt.len());
        self.mark_started();
        self.phase = SeqPhase::Prefilling { done };
        self.backend = backend;
        self.cached_prefix += done;
    }

    /// Total tokens this sequence will hold after `extra` more are added.
    pub fn tokens_with(&self, extra: usize) -> usize {
        let done = match self.phase {
            SeqPhase::Waiting => 0,
            SeqPhase::Prefilling { done } => done,
            _ => self.req.prompt.len() + self.emitted.len(),
        };
        done + extra
    }

    pub fn is_finished(&self) -> bool {
        self.phase == SeqPhase::Finished
    }

    fn should_stop(&self, tok: u32) -> bool {
        // count folded (pre-preemption) response tokens toward max_new so
        // a preempted sequence completes with identical output
        self.emitted_total() >= self.req.max_new || self.req.stop_token == Some(tok)
    }

    /// Run one unit of prefill work (`chunk` tokens).  Returns tokens consumed.
    pub fn step_prefill(&mut self, chunk: usize) -> usize {
        let done = match self.phase {
            SeqPhase::Waiting => 0,
            SeqPhase::Prefilling { done } => done,
            _ => return 0,
        };
        self.mark_started();
        let remaining = self.req.prompt.len() - done;
        let take = chunk.min(remaining);
        let last = done + take >= self.req.prompt.len();
        let logits = self.backend.prefill_chunk(&self.req.prompt[done..done + take], last);
        if last {
            // analyze: allow(panic-path) — SeqBackend contract: `last == true` implies Some
            self.pending_logits = Some(logits.expect("backend must return logits on final chunk"));
            self.phase = SeqPhase::Decoding;
        } else {
            self.phase = SeqPhase::Prefilling { done: done + take };
        }
        take
    }

    /// Run one decode step.  Returns the emitted token.
    pub fn step_decode(&mut self) -> u32 {
        debug_assert_eq!(self.phase, SeqPhase::Decoding);
        let logits = match self.pending_logits.take() {
            Some(l) => l,
            None => {
                // analyze: allow(panic-path) — Decoding phase implies a prior emit or buffered logits
                let last = *self.emitted.last().expect("decode without pending logits");
                self.backend.decode(last)
            }
        };
        self.apply_decoded_logits(&logits)
    }

    /// The token a batched decode pass must feed this sequence, or `None`
    /// when logits are already buffered (prefill just completed) and no
    /// forward pass is needed this step.
    pub fn decode_input(&self) -> Option<u32> {
        if self.pending_logits.is_some() {
            None
        } else {
            // analyze: allow(panic-path) — Decoding phase implies a prior emit or buffered logits
            Some(*self.emitted.last().expect("decode without pending logits"))
        }
    }

    /// Token-selection bookkeeping for one decode step whose logits were
    /// computed externally (the step-batched engine path): sample per
    /// `req.sampling`, emit, stream the `Token` event, stop/finish
    /// accounting.  Shared with [`Sequence::step_decode`] so batched and
    /// sequential execution retire tokens identically — and since the
    /// sampling RNG is keyed by `(seed, lifetime response position)`,
    /// preemption recompute replays pick the same tokens too.
    pub fn apply_decoded_logits(&mut self, logits: &[f32]) -> u32 {
        debug_assert_eq!(self.phase, SeqPhase::Decoding);
        let pos = self.emitted_total();
        let tok = self.req.sampling.sample(logits, pos);
        if self.first_token_at.is_none() {
            // analyze: allow(determinism) — TTFT metric timestamp; token choice is seed-keyed
            self.first_token_at = Some(Instant::now());
        }
        self.emitted.push(tok);
        self.session.send(Event::Token { pos, tok });
        if self.should_stop(tok) {
            self.phase = SeqPhase::Finished;
            // analyze: allow(determinism) — completion timestamp for metrics only
            self.finished_at = Some(Instant::now());
        }
        tok
    }

    /// Preempt: forget backend state; prompt + emitted tokens will be
    /// recomputed when rescheduled (recompute-style preemption).
    pub fn preempt(&mut self, fresh_backend: Box<dyn SeqBackend>) {
        // fold emitted tokens into the prompt so recompute replays them
        self.req.prompt.extend(self.emitted.drain(..));
        self.backend = fresh_backend;
        self.pending_logits = None;
        self.phase = SeqPhase::Waiting;
        self.preemptions += 1;
    }
}

#[cfg(test)]
pub(crate) mod test_backend {
    use super::*;

    /// Deterministic toy backend: logits always argmax to `next`, bumping
    /// each call; used for scheduler tests.
    pub struct ToyBackend {
        pub vocab: usize,
        pub next: u32,
        pub prefilled: usize,
        pub decoded: usize,
    }

    impl ToyBackend {
        pub fn new(vocab: usize) -> Self {
            Self { vocab, next: 1, prefilled: 0, decoded: 0 }
        }

        fn logits_for(&self, tok: u32) -> Vec<f32> {
            let mut l = vec![0.0; self.vocab];
            l[tok as usize % self.vocab] = 1.0;
            l
        }
    }

    impl SeqBackend for ToyBackend {
        fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
            self.prefilled += tokens.len();
            Some(self.logits_for(self.next))
        }

        fn decode(&mut self, _token: u32) -> Vec<f32> {
            self.decoded += 1;
            self.next += 1;
            self.logits_for(self.next)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_backend::ToyBackend;
    use super::*;

    fn seq(prompt_len: usize, max_new: usize) -> Sequence {
        Sequence::new(
            Request::new((0..prompt_len as u32).collect()).max_new(max_new),
            Session::detached(),
            Box::new(ToyBackend::new(64)),
        )
    }

    #[test]
    fn chunked_prefill_then_decode() {
        let mut s = seq(100, 3);
        assert_eq!(s.step_prefill(64), 64);
        assert_eq!(s.phase, SeqPhase::Prefilling { done: 64 });
        assert_eq!(s.step_prefill(64), 36);
        assert_eq!(s.phase, SeqPhase::Decoding);
        s.step_decode();
        s.step_decode();
        s.step_decode();
        assert!(s.is_finished());
        assert_eq!(s.emitted.len(), 3);
    }

    #[test]
    fn stop_token_ends_early() {
        let mut s = seq(10, 100);
        s.req.stop_token = Some(1); // toy backend emits 1 first
        s.step_prefill(64);
        s.step_decode();
        assert!(s.is_finished());
        assert_eq!(s.emitted, vec![1]);
    }

    #[test]
    fn preemption_folds_emitted_into_prompt() {
        let mut s = seq(10, 5);
        s.step_prefill(64);
        s.step_decode();
        assert_eq!(s.emitted.len(), 1);
        s.preempt(Box::new(ToyBackend::new(64)));
        assert_eq!(s.phase, SeqPhase::Waiting);
        assert_eq!(s.req.prompt.len(), 11);
        assert!(s.emitted.is_empty());
        assert_eq!(s.preemptions, 1);
    }

    #[test]
    fn tokens_with_accounting() {
        let mut s = seq(100, 5);
        assert_eq!(s.tokens_with(64), 64);
        s.step_prefill(64);
        assert_eq!(s.tokens_with(36), 100);
    }

    #[test]
    fn events_stream_started_tokens_and_positions() {
        use super::super::api::{handle_pair, Event};
        let stats = std::sync::Arc::new(std::sync::Mutex::new(crate::stats::LatencyHist::new()));
        let (mut h, session) = handle_pair(1, stats);
        let mut s = Sequence::new(
            Request::new((0..20).collect()).max_new(3),
            session,
            Box::new(ToyBackend::new(64)),
        );
        s.step_prefill(64);
        s.step_decode();
        s.step_decode();
        s.step_decode();
        assert!(s.is_finished());
        assert!(matches!(h.try_next(), Some(Event::Started)));
        let mut streamed = Vec::new();
        while let Some(ev) = h.try_next() {
            if let Event::Token { pos, tok } = ev {
                assert_eq!(pos, streamed.len(), "positions must be dense from 0");
                streamed.push(tok);
            }
        }
        assert_eq!(streamed, s.emitted, "streamed tokens mirror emissions");
    }

    #[test]
    fn started_not_resent_after_preemption() {
        use super::super::api::{handle_pair, Event};
        let stats = std::sync::Arc::new(std::sync::Mutex::new(crate::stats::LatencyHist::new()));
        let (mut h, session) = handle_pair(1, stats);
        let mut s = Sequence::new(
            Request::new((0..10).collect()).max_new(5),
            session,
            Box::new(ToyBackend::new(64)),
        );
        s.step_prefill(64);
        s.step_decode();
        s.preempt(Box::new(ToyBackend::new(64)));
        s.step_prefill(64); // re-admission prefill
        let starts = {
            let mut n = 0;
            while let Some(ev) = h.try_next() {
                if matches!(ev, Event::Started) {
                    n += 1;
                }
            }
            n
        };
        assert_eq!(starts, 1, "preemption re-admission is not a second start");
    }

    #[test]
    fn seeded_sampling_drives_emission() {
        use crate::config::SamplingParams;
        // backend emitting flat-ish logits so sampling (not argmax)
        // decides; identical seeds must replay identically
        struct Flat;
        impl SeqBackend for Flat {
            fn prefill_chunk(&mut self, _t: &[u32], _l: bool) -> Option<Vec<f32>> {
                Some((0..16).map(|i| (i as f32 * 0.37).sin()).collect())
            }
            fn decode(&mut self, token: u32) -> Vec<f32> {
                (0..16).map(|i| ((i + token as usize) as f32 * 0.53).sin()).collect()
            }
        }
        let run = |seed: u64| -> Vec<u32> {
            let mut s = Sequence::new(
                Request::new((0..8).collect())
                    .max_new(6)
                    .sampling(SamplingParams::seeded(seed)),
                Session::detached(),
                Box::new(Flat),
            );
            s.step_prefill(64);
            while !s.is_finished() {
                s.step_decode();
            }
            s.emitted.clone()
        };
        assert_eq!(run(7), run(7), "same seed must replay identically");
        assert_ne!(run(7), run(8), "different seeds must diverge");
    }
}
