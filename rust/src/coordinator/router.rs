//! Request router: spreads sequences across worker executors with session
//! affinity (same session lands on the same worker, preserving any warm
//! prefix state) and least-loaded fallback — the vllm-project/router
//! pattern scaled to this repo.

#[derive(Debug)]
pub struct Router {
    loads: Vec<usize>,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { loads: vec![0; workers] }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    fn hash(session: u64) -> u64 {
        // splitmix-style finalizer
        let mut z = session.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Route a request.  `session` pins affinity when `Some`; otherwise the
    /// least-loaded worker wins.
    pub fn route(&mut self, session: Option<u64>) -> usize {
        let w = match session {
            Some(s) => (Self::hash(s) % self.loads.len() as u64) as usize,
            None => {
                let mut best = 0;
                for i in 1..self.loads.len() {
                    if self.loads[i] < self.loads[best] {
                        best = i;
                    }
                }
                best
            }
        };
        self.loads[w] += 1;
        w
    }

    pub fn release(&mut self, worker: usize) {
        self.loads[worker] = self.loads[worker].saturating_sub(1);
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_affinity_is_stable() {
        let mut r = Router::new(4);
        let w1 = r.route(Some(42));
        for _ in 0..10 {
            assert_eq!(r.route(Some(42)), w1);
        }
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(3);
        for _ in 0..30 {
            r.route(None);
        }
        for w in 0..3 {
            assert_eq!(r.load(w), 10);
        }
    }

    #[test]
    fn release_rebalances() {
        let mut r = Router::new(2);
        let a = r.route(None);
        let _b = r.route(None);
        r.release(a);
        // worker `a` is now less loaded and must win
        assert_eq!(r.route(None), a);
    }

    #[test]
    fn sessions_spread_over_workers() {
        let mut r = Router::new(8);
        let mut seen = std::collections::HashSet::new();
        for s in 0..256u64 {
            seen.insert(r.route(Some(s)));
        }
        assert!(seen.len() >= 6, "sessions landed on only {} workers", seen.len());
    }
}
