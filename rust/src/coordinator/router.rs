//! Request router: spreads sequences across worker executors with session
//! affinity (same session lands on the same worker, preserving any warm
//! prefix state) and least-loaded fallback — the vllm-project/router
//! pattern scaled to this repo.  Workers whose threads died are marked
//! dead and skipped: affinity linearly probes to the next alive worker
//! (stable for a fixed death set), and `route` returns `None` only when
//! every worker is dead.

#[derive(Debug)]
pub struct Router {
    loads: Vec<usize>,
    dead: Vec<bool>,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { loads: vec![0; workers], dead: vec![false; workers] }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    /// Stop routing to `worker` (its thread died or was shut down).
    /// Unknown ids are ignored.
    pub fn mark_dead(&mut self, worker: usize) {
        if let Some(d) = self.dead.get_mut(worker) {
            *d = true;
        }
    }

    /// Unknown worker ids count as dead: never route to them.
    pub fn is_dead(&self, worker: usize) -> bool {
        self.dead.get(worker).copied().unwrap_or(true)
    }

    pub fn alive_workers(&self) -> usize {
        self.dead.iter().filter(|d| !**d).count()
    }

    fn hash(session: u64) -> u64 {
        crate::tensor::splitmix64(session.wrapping_add(0x9E37_79B9_7F4A_7C15))
    }

    /// Route a request.  `session` pins affinity when `Some` (probing
    /// past dead workers); otherwise the least-loaded alive worker wins.
    /// `None` when no worker is alive.
    pub fn route(&mut self, session: Option<u64>) -> Option<usize> {
        if self.alive_workers() == 0 {
            return None;
        }
        let n = self.loads.len();
        let w = match session {
            Some(s) => {
                let mut w = (Self::hash(s) % n as u64) as usize;
                while self.dead[w] {
                    w = (w + 1) % n;
                }
                w
            }
            None => {
                let mut best: Option<usize> = None;
                for i in 0..n {
                    if self.dead[i] {
                        continue;
                    }
                    best = match best {
                        Some(b) if self.loads[b] <= self.loads[i] => Some(b),
                        _ => Some(i),
                    };
                }
                best?
            }
        };
        self.loads[w] += 1;
        Some(w)
    }

    pub fn release(&mut self, worker: usize) {
        if let Some(l) = self.loads.get_mut(worker) {
            *l = l.saturating_sub(1);
        }
    }

    pub fn load(&self, worker: usize) -> usize {
        self.loads.get(worker).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_affinity_is_stable() {
        let mut r = Router::new(4);
        let w1 = r.route(Some(42)).unwrap();
        for _ in 0..10 {
            assert_eq!(r.route(Some(42)).unwrap(), w1);
        }
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(3);
        for _ in 0..30 {
            r.route(None).unwrap();
        }
        for w in 0..3 {
            assert_eq!(r.load(w), 10);
        }
    }

    #[test]
    fn release_rebalances() {
        let mut r = Router::new(2);
        let a = r.route(None).unwrap();
        let _b = r.route(None).unwrap();
        r.release(a);
        // worker `a` is now less loaded and must win
        assert_eq!(r.route(None).unwrap(), a);
    }

    #[test]
    fn sessions_spread_over_workers() {
        let mut r = Router::new(8);
        let mut seen = std::collections::HashSet::new();
        for s in 0..256u64 {
            seen.insert(r.route(Some(s)).unwrap());
        }
        assert!(seen.len() >= 6, "sessions landed on only {} workers", seen.len());
    }

    #[test]
    fn dead_workers_are_skipped_with_stable_reaffinity() {
        let mut r = Router::new(4);
        // find a session pinned to worker 0, then kill worker 0
        let s = (0..1024u64).find(|&s| {
            let mut probe = Router::new(4);
            probe.route(Some(s)) == Some(0)
        });
        let s = s.expect("some session hashes to worker 0");
        assert_eq!(r.route(Some(s)), Some(0));
        r.mark_dead(0);
        let w = r.route(Some(s)).unwrap();
        assert_ne!(w, 0, "dead worker must be skipped");
        for _ in 0..10 {
            assert_eq!(r.route(Some(s)).unwrap(), w, "re-affinity must be stable");
        }
        // least-loaded fallback also skips the dead worker
        for _ in 0..30 {
            assert_ne!(r.route(None).unwrap(), 0);
        }
        assert_eq!(r.alive_workers(), 3);
    }

    #[test]
    fn all_dead_routes_none() {
        let mut r = Router::new(2);
        r.mark_dead(0);
        r.mark_dead(1);
        assert_eq!(r.route(Some(1)), None);
        assert_eq!(r.route(None), None);
        assert_eq!(r.alive_workers(), 0);
    }
}
