//! Paged KV-cache block manager (vLLM-style) with refcounted,
//! copy-on-write block sharing and a prefix-cache retention pool.
//!
//! Tracks block ownership per sequence; allocation is in whole blocks of
//! `block_size` tokens.  The manager is the admission-control authority:
//! a sequence may only be scheduled if its next chunk's blocks can be
//! allocated, and the scheduler preempts (drops refs on + requeues) the
//! youngest running sequence when decode would otherwise OOM.
//!
//! Every physical block is in exactly one of three states:
//!
//! * **free** — on the free list, contents meaningless;
//! * **in_use** — referenced by >= 1 sequence (refcount > 0).  Full
//!   blocks registered in the prefix index may be referenced by several
//!   sequences at once (shared prompt prefixes, forks);
//! * **cached** — refcount 0 but still registered in the prefix index:
//!   retained on an LRU queue so a later sequence with the same prefix
//!   can re-adopt it without re-prefilling.  Evicted (oldest first) when
//!   allocation needs blocks or the pool exceeds its capacity.
//!
//! `free + in_use + cached == num_blocks` always holds (checked by
//! [`BlockManager::check_invariants`] and the property tests below).
//!
//! Appends only ever write into the single partially-filled tail block of
//! a sequence.  If that tail is shared (refcount > 1 — e.g. after
//! [`BlockManager::fork`]), the append triggers copy-on-write: the writer
//! gets a fresh block and drops its ref on the shared one.

use crate::config::KvDtype;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Per-sequence resident-tile ledger for tiered KV storage
/// (`docs/kv-tiers.md`): which completed KV tiles the manager believes
/// are hot, with LRU stamps.  The ledger is the *planning* view — the
/// per-layer caches are the ground truth, and drift (e.g. demand
/// promotions the planner never saw) self-heals through the caches' own
/// `ensure_hot_*` backstop.
#[derive(Debug, Default)]
struct TileLedger {
    /// tile id -> LRU stamp (stamps are unique per ledger)
    resident: BTreeMap<u32, u64>,
    clock: u64,
}

#[derive(Debug)]
pub struct BlockManager {
    pub block_size: usize,
    pub num_blocks: usize,
    /// storage mode stamped onto newly allocated blocks
    dtype: KvDtype,
    /// per-block storage mode: set at allocation, preserved across
    /// sharing (adopt / fork / prefix-cache parking) — a CoW-shared int8
    /// block stays int8 for every owner and is never re-quantized
    block_dtype: Vec<KvDtype>,
    free: Vec<u32>,
    /// per-block owner count (number of sequences whose table lists it)
    refc: Vec<u32>,
    /// per-block: registered in the prefix index (content addressable)
    indexed: Vec<bool>,
    /// per-sequence block tables; tables of different sequences may share
    /// physical blocks (never twice within one table)
    owned: HashMap<u64, Vec<u32>>,
    /// tokens currently stored per sequence (for block arithmetic)
    tokens: HashMap<u64, usize>,
    /// refcount-0 indexed blocks retained for prefix reuse, oldest first
    lru: VecDeque<u32>,
    /// blocks evicted from the cached pool since the last
    /// [`BlockManager::take_evicted`] (the scheduler uses this to drop
    /// the corresponding prefix-index entries)
    evicted: Vec<u32>,
    /// max blocks retained in the cached pool (0 disables retention)
    cache_cap: usize,
    /// copy-on-write block copies performed
    pub cow_copies: u64,
    /// high-water mark of in-use blocks
    pub peak_used: usize,
    /// hot-tile budget per sequence (0 = tiering off; see
    /// [`BlockManager::plan_tiles`])
    tile_budget: usize,
    /// per-sequence resident-tile ledgers (tiered KV only)
    tiles: HashMap<u64, TileLedger>,
}

impl BlockManager {
    pub fn new(block_size: usize, num_blocks: usize) -> Self {
        Self {
            block_size,
            num_blocks,
            dtype: KvDtype::F32,
            block_dtype: vec![KvDtype::F32; num_blocks],
            free: (0..num_blocks as u32).rev().collect(),
            refc: vec![0; num_blocks],
            indexed: vec![false; num_blocks],
            owned: HashMap::new(),
            tokens: HashMap::new(),
            lru: VecDeque::new(),
            evicted: Vec::new(),
            cache_cap: 0,
            cow_copies: 0,
            peak_used: 0,
            tile_budget: 0,
            tiles: HashMap::new(),
        }
    }

    /// Enable sparsity-aware KV tiering: per sequence, at most `budget`
    /// completed tiles are planned hot per layer
    /// ([`crate::config::ServeConfig::hot_tile_budget`]).
    pub fn set_tile_budget(&mut self, budget: usize) {
        self.tile_budget = budget;
    }

    /// Tick-boundary tile plan for `seq` (`docs/kv-tiers.md`): fold the
    /// policy's `needed` hint (sorted, deduplicated tile ids) into the
    /// sequence's resident ledger and emit which tiles to promote (newly
    /// needed) and demote (LRU beyond the hot budget, never a tile
    /// needed this round).  Deterministic: ledger iteration is ordered
    /// and LRU stamps are unique, so identical histories produce
    /// identical plans.  Tiles at or beyond `n_tiles` (truncated away)
    /// are forgotten silently.
    pub fn plan_tiles(
        &mut self,
        seq: u64,
        needed: &[u32],
        n_tiles: usize,
        promote: &mut Vec<u32>,
        demote: &mut Vec<u32>,
    ) {
        promote.clear();
        demote.clear();
        if self.tile_budget == 0 {
            return;
        }
        let led = self.tiles.entry(seq).or_default();
        led.resident.retain(|&t, _| (t as usize) < n_tiles);
        for &t in needed {
            if (t as usize) >= n_tiles {
                continue;
            }
            led.clock += 1;
            if led.resident.insert(t, led.clock).is_none() {
                promote.push(t);
            }
        }
        while led.resident.len() > self.tile_budget {
            let victim = led
                .resident
                .iter()
                .filter(|(t, _)| needed.binary_search(t).is_err())
                .min_by_key(|&(&t, &s)| (s, t))
                .map(|(&t, _)| t);
            let Some(v) = victim else {
                break; // every resident tile is needed: keep them all
            };
            led.resident.remove(&v);
            demote.push(v);
        }
    }

    /// Planned-resident tile count for `seq` (tests/diagnostics).
    pub fn planned_tiles(&self, seq: u64) -> usize {
        self.tiles.get(&seq).map_or(0, |l| l.resident.len())
    }

    /// Enable prefix-cache retention: up to `cap` refcount-0 indexed
    /// blocks are kept adoptable instead of being freed.
    pub fn set_cache_capacity(&mut self, cap: usize) {
        self.cache_cap = cap;
    }

    /// Storage mode for blocks allocated from now on
    /// ([`crate::config::ServeConfig::kv_dtype`]).  Existing blocks keep
    /// the mode they were written in.
    pub fn set_dtype(&mut self, dtype: KvDtype) {
        self.dtype = dtype;
    }

    /// The storage mode block `b` was allocated under.  An id outside
    /// the arena reports the current default mode.
    pub fn block_dtype_of(&self, b: u32) -> KvDtype {
        self.block_dtype.get(b as usize).copied().unwrap_or(self.dtype)
    }

    /// Whether block `i` holds live content: referenced by a sequence,
    /// or parked in the cached pool (refc 0 + indexed <=> on the LRU —
    /// `drop_ref` un-indexes any block it frees).
    #[inline]
    fn is_live(&self, i: usize) -> bool {
        // analyze: allow(panic-path) — private helper; callers iterate 0..num_blocks
        self.refc[i] > 0 || self.indexed[i]
    }

    /// Live (in-use or cached) blocks stored in a compressed mode (f16,
    /// int8, or int4).  O(num_blocks).
    pub fn quantized_blocks(&self) -> usize {
        (0..self.num_blocks)
            .filter(|&i| self.block_dtype[i].is_compressed() && self.is_live(i))
            .count()
    }

    /// Estimated KV bytes held by live (in-use + cached) blocks, given
    /// the f32 cost of one full block.  F16 blocks count half, int8 a
    /// quarter, int4 an eighth (the per-tile scale overhead is ignored
    /// here; exact per-sequence bytes come from
    /// [`crate::coordinator::SeqBackend::kv_stats`]).  O(num_blocks).
    pub fn kv_bytes_est(&self, f32_bytes_per_block: usize) -> usize {
        (0..self.num_blocks)
            .filter(|&i| self.is_live(i))
            .map(|i| match self.block_dtype[i] {
                KvDtype::F32 => f32_bytes_per_block,
                KvDtype::F16 => f32_bytes_per_block / 2,
                KvDtype::Int8 => f32_bytes_per_block / 4,
                KvDtype::Int4 => f32_bytes_per_block / 8,
            })
            .sum()
    }

    /// Blocks actively referenced by sequences.
    pub fn used(&self) -> usize {
        self.num_blocks - self.free.len() - self.lru.len()
    }

    /// Refcount-0 blocks retained in the prefix-cache pool.
    pub fn cached(&self) -> usize {
        self.lru.len()
    }

    /// Blocks an allocation could obtain (free + evictable cached).
    pub fn available(&self) -> usize {
        self.free.len() + self.lru.len()
    }

    pub fn utilization(&self) -> f64 {
        self.used() as f64 / self.num_blocks as f64
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Blocks that would be needed to extend `seq` to `new_tokens` total.
    pub fn extra_blocks_needed(&self, seq: u64, new_tokens: usize) -> usize {
        let have = self.owned.get(&seq).map_or(0, |v| v.len());
        self.blocks_for(new_tokens).saturating_sub(have)
    }

    /// Whether appending to `new_tokens` writes into a shared partial
    /// tail block (which costs one extra block for the private copy).
    fn cow_needed(&self, seq: u64, new_tokens: usize) -> bool {
        let t = self.tokens_of(seq);
        if new_tokens <= t || t % self.block_size == 0 {
            return false;
        }
        let tail_idx = t / self.block_size;
        match self.owned.get(&seq) {
            Some(bs) if tail_idx < bs.len() => self.refc[bs[tail_idx] as usize] > 1,
            _ => false,
        }
    }

    pub fn can_extend(&self, seq: u64, new_tokens: usize) -> bool {
        let cow = if self.cow_needed(seq, new_tokens) { 1 } else { 0 };
        self.extra_blocks_needed(seq, new_tokens) + cow <= self.available()
    }

    /// Pop a block for allocation, evicting the oldest cached block when
    /// the free list is empty.
    fn alloc_one(&mut self) -> Option<u32> {
        if let Some(b) = self.free.pop() {
            self.block_dtype[b as usize] = self.dtype;
            return Some(b);
        }
        let b = self.lru.pop_front()?;
        self.indexed[b as usize] = false;
        self.evicted.push(b);
        self.block_dtype[b as usize] = self.dtype;
        Some(b)
    }

    /// Drop one reference; a block reaching refcount 0 either parks in
    /// the cached pool (if indexed and retention is on) or frees.
    fn drop_ref(&mut self, b: u32) {
        let i = b as usize;
        debug_assert!(self.refc[i] > 0, "refcount underflow on block {b}");
        self.refc[i] -= 1;
        if self.refc[i] > 0 {
            return;
        }
        if self.indexed[i] && self.cache_cap > 0 {
            self.lru.push_back(b);
            while self.lru.len() > self.cache_cap {
                let Some(ev) = self.lru.pop_front() else { break };
                self.indexed[ev as usize] = false;
                self.evicted.push(ev);
                self.free.push(ev);
            }
        } else {
            if self.indexed[i] {
                self.indexed[i] = false;
                self.evicted.push(b);
            }
            self.free.push(b);
        }
    }

    /// Extend `seq` to `new_tokens` total tokens, copy-on-writing a
    /// shared partial tail block if needed.  Returns false (no change)
    /// if blocks are unavailable.
    pub fn extend(&mut self, seq: u64, new_tokens: usize) -> bool {
        let cow = self.cow_needed(seq, new_tokens);
        let need = self.extra_blocks_needed(seq, new_tokens) + if cow { 1 } else { 0 };
        if need > self.available() {
            return false;
        }
        if cow {
            let tail_idx = self.tokens_of(seq) / self.block_size;
            // analyze: allow(panic-path) — `need <= available()` verified above covers this alloc
            let fresh = self.alloc_one().expect("capacity checked above");
            self.refc[fresh as usize] = 1;
            // analyze: allow(panic-path) — cow_needed() true implies `seq` owns a tail block
            let bs = self.owned.get_mut(&seq).expect("cow implies ownership");
            let old = bs[tail_idx];
            bs[tail_idx] = fresh;
            self.drop_ref(old);
            self.cow_copies += 1;
        }
        let extra = self.extra_blocks_needed(seq, new_tokens);
        for _ in 0..extra {
            // analyze: allow(panic-path) — `need <= available()` verified above covers this alloc
            let b = self.alloc_one().expect("capacity checked above");
            self.refc[b as usize] = 1;
            self.owned.entry(seq).or_default().push(b);
        }
        self.tokens.insert(seq, new_tokens);
        self.peak_used = self.peak_used.max(self.used());
        true
    }

    /// Drop every reference of `seq` (finish or preemption).  Shared
    /// blocks survive under their other owners; exclusive indexed blocks
    /// park in the cached pool; the rest free.
    pub fn release(&mut self, seq: u64) {
        if let Some(blocks) = self.owned.remove(&seq) {
            for b in blocks {
                self.drop_ref(b);
            }
        }
        self.tokens.remove(&seq);
        self.tiles.remove(&seq);
    }

    /// Give `seq` shared references to `blocks` — a chain of full,
    /// indexed blocks (a cached prefix) covering exactly
    /// `blocks.len() * block_size` tokens.  The sequence must not
    /// currently own blocks.
    pub fn adopt(&mut self, seq: u64, blocks: &[u32], tokens: usize) {
        debug_assert!(self.owned.get(&seq).map_or(true, |v| v.is_empty()));
        debug_assert_eq!(tokens, blocks.len() * self.block_size);
        for &b in blocks {
            let i = b as usize;
            debug_assert!(self.indexed[i], "adopting unindexed block {b}");
            if self.refc[i] == 0 {
                // O(pool) scan per revived block; adoption is per-admission
                // (not per-tick-per-seq), so this stays off the decode hot
                // path — swap for a block->slot map if admission ever shows
                // up in the coordinator bench
                let pos = self.lru.iter().position(|&x| x == b);
                debug_assert!(pos.is_some(), "refcount-0 block {b} missing from cache pool");
                if let Some(p) = pos {
                    self.lru.remove(p);
                }
            }
            self.refc[i] += 1;
        }
        self.owned.insert(seq, blocks.to_vec());
        self.tokens.insert(seq, tokens);
        self.peak_used = self.peak_used.max(self.used());
    }

    /// Share every block of `parent` with `child` (parallel-sampling
    /// fork).  The child starts at the parent's token count; whichever
    /// side appends into the shared partial tail first copies-on-write.
    pub fn fork(&mut self, parent: u64, child: u64) -> bool {
        let bs = match self.owned.get(&parent) {
            Some(bs) => bs.clone(),
            None => return false,
        };
        if self.owned.get(&child).map_or(false, |v| !v.is_empty()) {
            return false;
        }
        for &b in &bs {
            self.refc[b as usize] += 1;
        }
        let t = self.tokens_of(parent);
        self.owned.insert(child, bs);
        self.tokens.insert(child, t);
        true
    }

    /// Mark an owned block as registered in the prefix index, making it
    /// shareable now and cacheable after its last ref drops.
    pub fn mark_indexed(&mut self, b: u32) {
        // analyze: allow(panic-path) — block ids come from this manager's own allocator;
        // an out-of-arena id is a logic bug worth the panic
        debug_assert!(self.refc[b as usize] > 0, "indexing unowned block {b}");
        self.indexed[b as usize] = true;
    }

    /// Whether a prefix-index entry pointing at `b` is still backed by
    /// live content (in use or parked in the cached pool).
    pub fn is_adoptable(&self, b: u32) -> bool {
        self.indexed.get(b as usize).copied().unwrap_or(false)
    }

    /// `j`-th block of `seq`'s table.
    pub fn block_of(&self, seq: u64, j: usize) -> Option<u32> {
        self.owned.get(&seq).and_then(|bs| bs.get(j).copied())
    }

    /// Drain the log of blocks evicted from the cached pool since the
    /// last call (their prefix-index entries must be forgotten).
    pub fn take_evicted(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.evicted)
    }

    pub fn tokens_of(&self, seq: u64) -> usize {
        self.tokens.get(&seq).copied().unwrap_or(0)
    }

    /// Invariant check (used by property tests): refcounts match owner
    /// tables exactly, no block is simultaneously free/cached/referenced,
    /// and `free + in_use + cached == num_blocks`.
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.num_blocks;
        // 0 = unseen, 1 = free, 2 = cached
        let mut state = vec![0u8; n];
        for &b in &self.free {
            let i = b as usize;
            if state[i] != 0 {
                return Err(format!("block {b} duplicated in free list"));
            }
            state[i] = 1;
            if self.refc[i] != 0 {
                return Err(format!("free block {b} has refcount {}", self.refc[i]));
            }
        }
        for &b in &self.lru {
            let i = b as usize;
            if state[i] != 0 {
                return Err(format!("cached block {b} also free or duplicated"));
            }
            state[i] = 2;
            if self.refc[i] != 0 {
                return Err(format!("cached block {b} has refcount {}", self.refc[i]));
            }
            if !self.indexed[i] {
                return Err(format!("cached block {b} is not indexed"));
            }
        }
        let mut refs = vec![0u32; n];
        for (seq, bs) in &self.owned {
            let mut sorted: Vec<u32> = bs.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != bs.len() {
                return Err(format!("seq {seq} lists a block twice"));
            }
            for &b in bs {
                if state[b as usize] != 0 {
                    return Err(format!("block {b} owned by seq {seq} but free/cached"));
                }
                refs[b as usize] += 1;
            }
            let t = self.tokens.get(seq).copied().unwrap_or(0);
            if bs.len() < self.blocks_for(t) {
                return Err(format!("seq {seq}: {} blocks < needed for {t} tokens", bs.len()));
            }
        }
        let mut in_use = 0usize;
        for b in 0..n {
            if refs[b] != self.refc[b] {
                return Err(format!(
                    "block {b}: refcount {} != {} owner references",
                    self.refc[b], refs[b]
                ));
            }
            if self.refc[b] > 0 {
                in_use += 1;
            } else if state[b] == 0 {
                return Err(format!("block {b} leaked (not free, cached, or referenced)"));
            }
        }
        if self.free.len() + in_use + self.lru.len() != n {
            return Err(format!(
                "free {} + in_use {in_use} + cached {} != {n}",
                self.free.len(),
                self.lru.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::proptest_lite::check;

    #[test]
    fn extend_and_release() {
        let mut bm = BlockManager::new(16, 8);
        assert!(bm.extend(1, 20)); // 2 blocks
        assert_eq!(bm.used(), 2);
        assert!(bm.extend(1, 33)); // 3 blocks total
        assert_eq!(bm.used(), 3);
        assert!(bm.extend(2, 80)); // 5 more
        assert_eq!(bm.used(), 8);
        assert!(!bm.extend(3, 1)); // exhausted
        bm.release(1);
        assert_eq!(bm.used(), 5);
        assert!(bm.extend(3, 40));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn extend_is_idempotent_within_block() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.extend(1, 15));
        assert_eq!(bm.used(), 1);
        assert!(bm.extend(1, 16));
        assert_eq!(bm.used(), 1); // same block
        assert!(bm.extend(1, 17));
        assert_eq!(bm.used(), 2);
    }

    #[test]
    fn failed_extend_changes_nothing() {
        let mut bm = BlockManager::new(16, 2);
        assert!(bm.extend(1, 32));
        let used = bm.used();
        assert!(!bm.extend(2, 16));
        assert_eq!(bm.used(), used);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_then_cow_on_append() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.extend(1, 24)); // 2 blocks, tail half-full
        assert!(bm.fork(1, 2));
        assert_eq!(bm.used(), 2, "fork allocates nothing");
        assert_eq!(bm.tokens_of(2), 24);
        bm.check_invariants().unwrap();
        // child appends into the shared partial tail -> private copy
        assert!(bm.extend(2, 25));
        assert_eq!(bm.cow_copies, 1);
        assert_eq!(bm.used(), 3);
        assert_ne!(bm.block_of(1, 1), bm.block_of(2, 1));
        assert_eq!(bm.block_of(1, 0), bm.block_of(2, 0), "full block stays shared");
        bm.check_invariants().unwrap();
        // parent's tail is exclusive again: no further copy
        assert!(bm.extend(1, 25));
        assert_eq!(bm.cow_copies, 1);
        bm.release(1);
        bm.release(2);
        assert_eq!(bm.used(), 0);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn indexed_blocks_park_in_cache_and_revive() {
        let mut bm = BlockManager::new(16, 4);
        bm.set_cache_capacity(4);
        assert!(bm.extend(1, 32)); // 2 full blocks
        let b0 = bm.block_of(1, 0).unwrap();
        let b1 = bm.block_of(1, 1).unwrap();
        bm.mark_indexed(b0);
        bm.mark_indexed(b1);
        bm.release(1);
        assert_eq!(bm.used(), 0);
        assert_eq!(bm.cached(), 2);
        bm.check_invariants().unwrap();
        // a new sequence adopts the cached chain
        bm.adopt(7, &[b0, b1], 32);
        assert_eq!(bm.cached(), 0);
        assert_eq!(bm.used(), 2);
        assert_eq!(bm.tokens_of(7), 32);
        bm.check_invariants().unwrap();
        // a second adopter shares the same physical blocks
        bm.adopt(8, &[b0, b1], 32);
        assert_eq!(bm.used(), 2);
        bm.check_invariants().unwrap();
        bm.release(7);
        assert_eq!(bm.used(), 2, "still referenced by 8");
        bm.release(8);
        assert_eq!(bm.cached(), 2);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn cached_blocks_are_evicted_lru_under_pressure() {
        let mut bm = BlockManager::new(16, 2);
        bm.set_cache_capacity(2);
        assert!(bm.extend(1, 32));
        let b0 = bm.block_of(1, 0).unwrap();
        let b1 = bm.block_of(1, 1).unwrap();
        bm.mark_indexed(b0);
        bm.mark_indexed(b1);
        bm.release(1);
        assert_eq!(bm.cached(), 2);
        // allocation must evict the oldest cached block, not fail
        assert!(bm.can_extend(2, 16));
        assert!(bm.extend(2, 16));
        assert_eq!(bm.cached(), 1);
        let evicted = bm.take_evicted();
        assert_eq!(evicted, vec![b0], "oldest first");
        assert!(!bm.is_adoptable(b0));
        assert!(bm.is_adoptable(b1));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn cache_capacity_bounds_the_pool() {
        let mut bm = BlockManager::new(16, 8);
        bm.set_cache_capacity(2);
        assert!(bm.extend(1, 16 * 5));
        for j in 0..5 {
            let b = bm.block_of(1, j).unwrap();
            bm.mark_indexed(b);
        }
        bm.release(1);
        assert_eq!(bm.cached(), 2, "pool capped");
        assert_eq!(bm.take_evicted().len(), 3);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn block_dtype_sticks_across_sharing_and_cow() {
        use crate::config::KvDtype;
        let mut bm = BlockManager::new(16, 8);
        bm.set_cache_capacity(8);
        bm.set_dtype(KvDtype::Int8);
        assert!(bm.extend(1, 24)); // 2 int8 blocks, partial tail
        let b0 = bm.block_of(1, 0).unwrap();
        assert_eq!(bm.block_dtype_of(b0), KvDtype::Int8);
        assert_eq!(bm.quantized_blocks(), 2);
        // fork shares the same physical blocks: mode unchanged, nothing
        // re-stamped (the shared int8 tiles are never re-quantized)
        assert!(bm.fork(1, 2));
        assert_eq!(bm.quantized_blocks(), 2);
        // CoW copy of the shared tail allocates under the CURRENT mode
        bm.set_dtype(KvDtype::F32);
        assert!(bm.extend(2, 25));
        let tail2 = bm.block_of(2, 1).unwrap();
        assert_eq!(bm.block_dtype_of(tail2), KvDtype::F32);
        assert_eq!(bm.block_dtype_of(b0), KvDtype::Int8, "shared block keeps its mode");
        // parking in the cache pool and re-adopting preserves the mode
        bm.mark_indexed(b0);
        bm.release(1);
        bm.release(2);
        assert_eq!(bm.block_dtype_of(b0), KvDtype::Int8);
        bm.adopt(7, &[b0], 16);
        assert_eq!(bm.block_dtype_of(b0), KvDtype::Int8);
        // byte estimate: int8 blocks count a quarter
        let est = bm.kv_bytes_est(1024);
        assert_eq!(est, 1024 / 4);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn tile_plans_respect_budget_and_lru_order() {
        let mut bm = BlockManager::new(16, 8);
        let (mut p, mut d) = (Vec::new(), Vec::new());
        // budget 0: tiering off, plans are empty
        bm.plan_tiles(1, &[0, 1, 2], 10, &mut p, &mut d);
        assert!(p.is_empty() && d.is_empty());
        bm.set_tile_budget(3);
        // first hint: everything promotes, nothing demotes
        bm.plan_tiles(1, &[0, 1, 2], 10, &mut p, &mut d);
        assert_eq!(p, vec![0, 1, 2]);
        assert!(d.is_empty());
        assert_eq!(bm.planned_tiles(1), 3);
        // new tiles displace the least-recently-needed ones
        bm.plan_tiles(1, &[4, 5], 10, &mut p, &mut d);
        assert_eq!(p, vec![4, 5]);
        assert_eq!(d, vec![0, 1], "LRU victims, oldest stamps first");
        assert_eq!(bm.planned_tiles(1), 3);
        // re-needing a resident tile refreshes it instead of promoting
        bm.plan_tiles(1, &[2, 6], 10, &mut p, &mut d);
        assert_eq!(p, vec![6]);
        assert_eq!(d, vec![4], "tile 2 was refreshed; 4 is now oldest");
        // needed tiles are never demoted, even over budget
        bm.plan_tiles(1, &[2, 5, 6, 7], 10, &mut p, &mut d);
        assert_eq!(p, vec![7]);
        assert!(d.is_empty(), "all four resident tiles are needed");
        assert_eq!(bm.planned_tiles(1), 4, "demand overshoot is allowed");
        // truncation forgets out-of-range tiles silently
        bm.plan_tiles(1, &[0], 1, &mut p, &mut d);
        assert_eq!(p, vec![0]);
        assert!(d.is_empty());
        assert_eq!(bm.planned_tiles(1), 1);
        // release drops the ledger
        bm.release(1);
        assert_eq!(bm.planned_tiles(1), 0);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn prop_random_alloc_free_preserves_invariants() {
        check("block manager invariants", 30, |rng| {
            let mut bm = BlockManager::new(1 + rng.below(32), 1 + rng.below(64));
            let mut live: Vec<u64> = Vec::new();
            for step in 0..200 {
                match rng.below(3) {
                    0 => {
                        let seq = rng.below(16) as u64;
                        let new_tokens = bm.tokens_of(seq) + 1 + rng.below(40);
                        if bm.extend(seq, new_tokens) && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                    1 => {
                        if let Some(&seq) = live.get(rng.below(live.len().max(1))) {
                            bm.release(seq);
                            live.retain(|&s| s != seq);
                        }
                    }
                    _ => {
                        let seq = rng.below(16) as u64;
                        let t = bm.tokens_of(seq) + rng.below(100);
                        let can = bm.can_extend(seq, t);
                        let did = bm.extend(seq, t);
                        prop_assert!(can == did, "step {step}: can {can} != did {did}");
                        if did && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                }
                if let Err(e) = bm.check_invariants() {
                    return Err(format!("step {step}: {e}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_share_release_preempt_preserves_invariants() {
        // the full lifecycle under sharing: random extend / index /
        // release / adopt / fork streams with the cache pool enabled
        check("block manager sharing invariants", 30, |rng| {
            let bs = 1 + rng.below(16);
            let nb = 4 + rng.below(60);
            let mut bm = BlockManager::new(bs, nb);
            bm.set_cache_capacity(1 + rng.below(nb));
            let mut live: Vec<u64> = Vec::new();
            // chains of (blocks, tokens) released into the cache pool
            let mut cached_chains: Vec<Vec<u32>> = Vec::new();
            let mut next_seq = 100u64;
            for step in 0..250 {
                match rng.below(6) {
                    0 | 1 => {
                        // extend a random (possibly new) sequence
                        let seq = if live.is_empty() || rng.below(3) == 0 {
                            next_seq += 1;
                            next_seq
                        } else {
                            live[rng.below(live.len())]
                        };
                        let t = bm.tokens_of(seq) + 1 + rng.below(3 * bs);
                        let can = bm.can_extend(seq, t);
                        let did = bm.extend(seq, t);
                        prop_assert!(can == did, "step {step}: can {can} != did {did}");
                        if did && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                    2 => {
                        // index the full blocks of a live sequence
                        if let Some(&seq) = live.get(rng.below(live.len().max(1))) {
                            let full = bm.tokens_of(seq) / bs;
                            for j in 0..full {
                                if let Some(b) = bm.block_of(seq, j) {
                                    bm.mark_indexed(b);
                                }
                            }
                        }
                    }
                    3 => {
                        // release (finish / preempt): refs drop, blocks
                        // survive in the pool or under other owners
                        if let Some(&seq) = live.get(rng.below(live.len().max(1))) {
                            let full = bm.tokens_of(seq) / bs;
                            let chain: Vec<u32> = (0..full)
                                .filter_map(|j| bm.block_of(seq, j))
                                .collect();
                            bm.release(seq);
                            live.retain(|&s| s != seq);
                            if !chain.is_empty() {
                                cached_chains.push(chain);
                            }
                        }
                    }
                    4 => {
                        // adopt a previously released chain (prefix hit),
                        // guarded exactly like the scheduler does
                        if let Some(chain) = cached_chains.pop() {
                            let alive = chain.iter().all(|&b| bm.is_adoptable(b));
                            if alive {
                                next_seq += 1;
                                bm.adopt(next_seq, &chain, chain.len() * bs);
                                live.push(next_seq);
                            }
                        }
                    }
                    _ => {
                        // fork a live sequence (CoW sharing of the tail)
                        if let Some(&seq) = live.get(rng.below(live.len().max(1))) {
                            next_seq += 1;
                            if bm.fork(seq, next_seq) {
                                live.push(next_seq);
                            }
                        }
                    }
                }
                bm.take_evicted();
                if let Err(e) = bm.check_invariants() {
                    return Err(format!("step {step}: {e}"));
                }
            }
            Ok(())
        });
    }
}
