//! Paged KV-cache block manager (vLLM-style).
//!
//! Tracks block ownership per sequence; allocation is in whole blocks of
//! `block_size` tokens.  The manager is the admission-control authority:
//! a sequence may only be scheduled if its next chunk's blocks can be
//! allocated, and the scheduler preempts (frees + requeues) the youngest
//! running sequence when decode would otherwise OOM.

use std::collections::HashMap;

#[derive(Debug)]
pub struct BlockManager {
    pub block_size: usize,
    pub num_blocks: usize,
    free: Vec<u32>,
    owned: HashMap<u64, Vec<u32>>,
    /// tokens currently stored per sequence (for block arithmetic)
    tokens: HashMap<u64, usize>,
    /// high-water mark of allocated blocks
    pub peak_used: usize,
}

impl BlockManager {
    pub fn new(block_size: usize, num_blocks: usize) -> Self {
        Self {
            block_size,
            num_blocks,
            free: (0..num_blocks as u32).rev().collect(),
            owned: HashMap::new(),
            tokens: HashMap::new(),
            peak_used: 0,
        }
    }

    pub fn used(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.used() as f64 / self.num_blocks as f64
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Blocks that would be needed to extend `seq` to `new_tokens` total.
    pub fn extra_blocks_needed(&self, seq: u64, new_tokens: usize) -> usize {
        let have = self.owned.get(&seq).map_or(0, |v| v.len());
        self.blocks_for(new_tokens).saturating_sub(have)
    }

    pub fn can_extend(&self, seq: u64, new_tokens: usize) -> bool {
        self.extra_blocks_needed(seq, new_tokens) <= self.free.len()
    }

    /// Extend `seq` to `new_tokens` total tokens.  Returns false (no
    /// change) if blocks are unavailable.
    pub fn extend(&mut self, seq: u64, new_tokens: usize) -> bool {
        let need = self.extra_blocks_needed(seq, new_tokens);
        if need > self.free.len() {
            return false;
        }
        let entry = self.owned.entry(seq).or_default();
        for _ in 0..need {
            entry.push(self.free.pop().unwrap());
        }
        self.tokens.insert(seq, new_tokens);
        self.peak_used = self.peak_used.max(self.num_blocks - self.free.len());
        true
    }

    /// Release every block of `seq` (finish or preemption).
    pub fn release(&mut self, seq: u64) {
        if let Some(blocks) = self.owned.remove(&seq) {
            self.free.extend(blocks);
        }
        self.tokens.remove(&seq);
    }

    pub fn tokens_of(&self, seq: u64) -> usize {
        self.tokens.get(&seq).copied().unwrap_or(0)
    }

    /// Invariant check (used by property tests): no block is double-owned
    /// and owned + free == total.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.num_blocks];
        for &b in &self.free {
            if seen[b as usize] {
                return Err(format!("block {b} duplicated in free list"));
            }
            seen[b as usize] = true;
        }
        for (seq, blocks) in &self.owned {
            for &b in blocks {
                if seen[b as usize] {
                    return Err(format!("block {b} double-owned (seq {seq})"));
                }
                seen[b as usize] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked blocks".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::proptest_lite::check;

    #[test]
    fn extend_and_release() {
        let mut bm = BlockManager::new(16, 8);
        assert!(bm.extend(1, 20)); // 2 blocks
        assert_eq!(bm.used(), 2);
        assert!(bm.extend(1, 33)); // 3 blocks total
        assert_eq!(bm.used(), 3);
        assert!(bm.extend(2, 80)); // 5 more
        assert_eq!(bm.used(), 8);
        assert!(!bm.extend(3, 1)); // exhausted
        bm.release(1);
        assert_eq!(bm.used(), 5);
        assert!(bm.extend(3, 40));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn extend_is_idempotent_within_block() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.extend(1, 15));
        assert_eq!(bm.used(), 1);
        assert!(bm.extend(1, 16));
        assert_eq!(bm.used(), 1); // same block
        assert!(bm.extend(1, 17));
        assert_eq!(bm.used(), 2);
    }

    #[test]
    fn failed_extend_changes_nothing() {
        let mut bm = BlockManager::new(16, 2);
        assert!(bm.extend(1, 32));
        let used = bm.used();
        assert!(!bm.extend(2, 16));
        assert_eq!(bm.used(), used);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn prop_random_alloc_free_preserves_invariants() {
        check("block manager invariants", 30, |rng| {
            let mut bm = BlockManager::new(1 + rng.below(32), 1 + rng.below(64));
            let mut live: Vec<u64> = Vec::new();
            for step in 0..200 {
                match rng.below(3) {
                    0 => {
                        let seq = rng.below(16) as u64;
                        let new_tokens = bm.tokens_of(seq) + 1 + rng.below(40);
                        if bm.extend(seq, new_tokens) && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                    1 => {
                        if let Some(&seq) = live.get(rng.below(live.len().max(1))) {
                            bm.release(seq);
                            live.retain(|&s| s != seq);
                        }
                    }
                    _ => {
                        let seq = rng.below(16) as u64;
                        let t = bm.tokens_of(seq) + rng.below(100);
                        let can = bm.can_extend(seq, t);
                        let did = bm.extend(seq, t);
                        prop_assert!(can == did, "step {step}: can {can} != did {did}");
                        if did && !live.contains(&seq) {
                            live.push(seq);
                        }
                    }
                }
                if let Err(e) = bm.check_invariants() {
                    return Err(format!("step {step}: {e}"));
                }
            }
            Ok(())
        });
    }
}
