//! Serving coordinator: the L3 system the paper's kernels plug into.
//!
//! vLLM-style composition: typed streaming requests enter through the
//! session API ([`api`]: request builder, per-token events, cancellation,
//! deadlines, seeded sampling), land in a bounded waiting queue
//! ([`scheduler`]), a continuous batcher forms per-tick work under a token
//! budget (chunked prefill + all running decodes), a paged KV block
//! manager ([`blocks`]) with refcounted copy-on-write sharing gates
//! admission and triggers preemption, an automatic prefix cache
//! ([`prefix_cache`]) lets sequences with equal prompt prefixes share
//! blocks and skip prefill compute, and a
//! router ([`router`]) spreads sequences across worker executors.  The
//! Kascade plan lives in the per-sequence backend: anchor layers refresh
//! the sequence's Top-k index state, reuse layers consume it (after head
//! remapping) — see [`crate::sparse::KascadePolicy`] (native path) and
//! [`crate::runtime::PjrtModel`] (PJRT path).

pub mod api;
pub mod backends;
pub mod blocks;
pub mod metrics;
pub mod prefix_cache;
pub mod router;
pub mod scheduler;
pub mod sequence;

pub use api::{
    handle_pair, Completion, Event, FailReason, Request, RequestHandle, Session, SubmitError,
};
pub use backends::{NativeBackend, PjrtBackend};
pub use blocks::BlockManager;
pub use metrics::ServeMetrics;
pub use prefix_cache::{chain_hashes, PrefixIndex, PrefixMatch, PrefixStats};
pub use router::Router;
pub use scheduler::{Batch, Scheduler, WorkItem};
pub use sequence::{BatchParts, KvStats, SeqBackend, SeqPhase, Sequence};
