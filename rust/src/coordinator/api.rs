//! Streaming session API: the typed front door of the serving stack.
//!
//! A client builds a [`Request`] (prompt + decode budget + stop token +
//! deadline + priority + [`SamplingParams`]), submits it to an
//! [`crate::server::Engine`] or [`crate::server::Server`], and receives a
//! [`RequestHandle`] that streams [`Event`]s: `Started` when the sequence
//! is admitted into the running batch, one `Token` per decoded token as
//! the engine ticks, and a terminal `Done(Completion)` or
//! `Failed(FailReason)`.  The handle's `cancel()` tears the request down
//! inside the engine within one tick — all KV blocks released, indexed
//! blocks parked in the prefix-cache pool (snapshots stay valid).
//!
//! Submission is typed end to end: admission failures are a synchronous
//! [`SubmitError`] (queue full, prompt too long, worker dead), not a
//! silent `false`.

use crate::stats::LatencyHist;
/// Re-exported: the typed token-selection rule lives in [`crate::config`]
/// so the model layer (`Model::sample_decode`) can share it.
pub use crate::config::SamplingParams;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Client request, assembled with the builder:
/// `Request::new(prompt).max_new(64).stop(eos).deadline_ms(500.0)
///  .priority(1).sampling(SamplingParams::seeded(42))`.
///
/// Request ids are assigned by the engine/server at submit and returned
/// through [`RequestHandle::id`] (and on the [`Completion`]).
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<u32>,
    /// Lifetime cap on emitted response tokens (preemption-folded tokens
    /// count — a preempted request completes with identical output).
    pub max_new: usize,
    /// Stop decoding when this token is emitted (in addition to max_new).
    pub stop_token: Option<u32>,
    /// Wall-clock budget from submission; expiry fails the request with
    /// [`FailReason::DeadlineExceeded`] and releases its blocks.
    pub deadline_ms: Option<f64>,
    /// Admission priority: higher jumps the waiting queue (FCFS within a
    /// priority level; preempted sequences keep their head-of-queue
    /// recovery slot).
    pub priority: i32,
    /// Tenant id for fair-share admission
    /// (`ServeConfig::fair_share`).  Requests from the same tenant share
    /// one admitted-token account; with fair-share off this is purely
    /// informational.  Default 0 (the anonymous tenant).
    pub tenant: u32,
    pub sampling: SamplingParams,
}

impl Request {
    pub fn new(prompt: Vec<u32>) -> Self {
        Self {
            prompt,
            max_new: 16,
            stop_token: None,
            deadline_ms: None,
            priority: 0,
            tenant: 0,
            sampling: SamplingParams::Greedy,
        }
    }

    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n;
        self
    }

    pub fn stop(mut self, tok: u32) -> Self {
        self.stop_token = Some(tok);
        self
    }

    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn priority(mut self, p: i32) -> Self {
        self.priority = p;
        self
    }

    pub fn tenant(mut self, t: u32) -> Self {
        self.tenant = t;
        self
    }

    pub fn sampling(mut self, s: SamplingParams) -> Self {
        self.sampling = s;
        self
    }
}

/// Typed admission failure (replaces the old `submit() -> bool`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The waiting queue is at `ServeConfig::queue_cap`.
    QueueFull,
    /// The prompt exceeds `ServeConfig::max_prompt_tokens` (or could
    /// never fit the block pool with one decode token).
    PromptTooLong { prompt: usize, limit: usize },
    /// No alive worker to route to.
    WorkerDead,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "waiting queue full"),
            SubmitError::PromptTooLong { prompt, limit } => {
                write!(f, "prompt of {prompt} tokens exceeds limit {limit}")
            }
            SubmitError::WorkerDead => write!(f, "no alive worker"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Finished-request report.  `ttft_ms` is `None` when no token was ever
/// emitted (e.g. cancelled during prefill) — never a silent `0.0`.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Submission -> first emitted token, engine-observed.  `None` if no
    /// token was emitted.
    pub ttft_ms: Option<f64>,
    /// Submission -> termination (finish, cancel, or deadline expiry).
    pub total_ms: Option<f64>,
    pub preemptions: usize,
    /// prompt tokens whose prefill was skipped via the prefix cache
    pub cached_prefix_tokens: usize,
}

/// Why a request terminated without completing.  `Cancelled` and
/// `DeadlineExceeded` carry the partial completion (tokens streamed so
/// far, `ttft_ms: None` if the request never produced one).
#[derive(Debug, Clone)]
pub enum FailReason {
    /// Rejected at admission; the request never ran.
    Rejected(SubmitError),
    /// The client called [`RequestHandle::cancel`].
    Cancelled(Completion),
    /// `Request::deadline_ms` elapsed before completion.
    DeadlineExceeded(Completion),
    /// The worker serving the request died (channel disconnected).
    WorkerDead,
    /// Client-side [`RequestHandle::wait`] timeout — the request may
    /// still be running; never sent by the engine itself.
    TimedOut,
}

impl FailReason {
    /// The partial completion, when the request got far enough to have one.
    pub fn partial(&self) -> Option<&Completion> {
        match self {
            FailReason::Cancelled(c) | FailReason::DeadlineExceeded(c) => Some(c),
            _ => None,
        }
    }
}

/// Per-request lifecycle events streamed to the [`RequestHandle`].
/// Ordering per request: `Started`, then `Token`s with strictly
/// increasing `pos` (the index into the final response), then exactly one
/// terminal `Done` or `Failed`.  A request rejected or cancelled before
/// admission sees only the terminal event.
#[derive(Debug, Clone)]
pub enum Event {
    Started,
    Token { pos: usize, tok: u32 },
    Done(Completion),
    Failed(FailReason),
}

/// Engine-side half of a session: the event sender plus the shared
/// cancellation flag.  Created by [`handle_pair`]; crosses into worker
/// threads with the request.
#[derive(Debug, Clone)]
pub struct Session {
    events: Sender<Event>,
    cancel: Arc<AtomicBool>,
    /// client-side submission instant — the epoch for `deadline_ms`,
    /// `ttft_ms` and `total_ms`, so channel queueing time (a busy
    /// `Server` worker draining late) counts against the budget
    created: Instant,
}

impl Session {
    /// Deliver an event to the handle (dropped handles discard silently).
    pub fn send(&self, ev: Event) {
        let _ = self.events.send(ev);
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// When the client submitted (the deadline/latency epoch).
    pub fn created(&self) -> Instant {
        self.created
    }

    /// A session with no listening handle — for driving a [`Sequence`]
    /// outside an engine (unit tests, type-level bench checks).
    ///
    /// [`Sequence`]: super::Sequence
    pub fn detached() -> Self {
        let (events, _rx) = channel();
        Self { events, cancel: Arc::new(AtomicBool::new(false)), created: Instant::now() }
    }
}

/// Client-side half of a session: streams [`Event`]s and exposes
/// `cancel()`.  With a [`crate::server::Server`] the worker thread ticks
/// for you — block on [`RequestHandle::wait`].  With a single-threaded
/// [`crate::server::Engine`] nothing runs while you block: interleave
/// `engine.tick()` with [`RequestHandle::try_next`] (or use
/// `Engine::run_to_completion`).
#[derive(Debug)]
pub struct RequestHandle {
    id: u64,
    rx: Receiver<Event>,
    cancel: Arc<AtomicBool>,
    created: Instant,
    /// handle-observed TTFT collector, shared with `ServeMetrics`
    streamed: Arc<Mutex<LatencyHist>>,
    saw_token: bool,
    terminal: bool,
}

/// Create a connected handle/session pair.  `streamed` receives the
/// handle-observed TTFT (submit -> first `Token` *observed by the
/// client*, queueing included — the latency a user actually sees, as
/// opposed to the engine-side `ServeMetrics::ttft_us`).
pub fn handle_pair(id: u64, streamed: Arc<Mutex<LatencyHist>>) -> (RequestHandle, Session) {
    let (events, rx) = channel();
    let cancel = Arc::new(AtomicBool::new(false));
    let created = Instant::now();
    let handle = RequestHandle {
        id,
        rx,
        cancel: cancel.clone(),
        created,
        streamed,
        saw_token: false,
        terminal: false,
    };
    (handle, Session { events, cancel, created })
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request teardown.  The engine applies it at the top of its next
    /// tick: the sequence leaves the scheduler, every KV block is
    /// released, and the handle receives
    /// `Failed(Cancelled(partial))`.  Idempotent; a no-op after the
    /// terminal event.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Whether the terminal event (`Done` / `Failed`) has been observed.
    pub fn is_terminal(&self) -> bool {
        self.terminal
    }

    fn observe(&mut self, ev: &Event) {
        match ev {
            Event::Token { .. } if !self.saw_token => {
                self.saw_token = true;
                let us = self.created.elapsed().as_secs_f64() * 1e6;
                if let Ok(mut h) = self.streamed.lock() {
                    h.add_us(us);
                }
            }
            Event::Done(_) | Event::Failed(_) => self.terminal = true,
            _ => {}
        }
    }

    /// Non-blocking: the next pending event, if any.
    pub fn try_next(&mut self) -> Option<Event> {
        match self.rx.try_recv() {
            Ok(ev) => {
                self.observe(&ev);
                Some(ev)
            }
            Err(_) => None,
        }
    }

    /// Blocking with timeout: the next event, or `None` on timeout /
    /// disconnection.
    pub fn next_timeout(&mut self, timeout: Duration) -> Option<Event> {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.observe(&ev);
                Some(ev)
            }
            Err(_) => None,
        }
    }

    /// Block until the terminal event (server usage).  Token events are
    /// consumed along the way (TTFT still recorded).  A disconnected
    /// worker surfaces as `Err(WorkerDead)`; running out of `timeout`
    /// as `Err(TimedOut)` — the request may still be running.
    pub fn wait(&mut self, timeout: Duration) -> Result<Completion, FailReason> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(left) {
                Ok(ev) => {
                    self.observe(&ev);
                    match ev {
                        Event::Done(c) => return Ok(c),
                        Event::Failed(f) => return Err(f),
                        _ => {}
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Err(FailReason::WorkerDead),
                Err(RecvTimeoutError::Timeout) => return Err(FailReason::TimedOut),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collector() -> Arc<Mutex<LatencyHist>> {
        Arc::new(Mutex::new(LatencyHist::new()))
    }

    #[test]
    fn builder_defaults_and_chaining() {
        let r = Request::new(vec![1, 2, 3]);
        assert_eq!(r.max_new, 16);
        assert_eq!(r.stop_token, None);
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.priority, 0);
        assert_eq!(r.tenant, 0);
        assert_eq!(r.sampling, SamplingParams::Greedy);
        let r = r
            .max_new(5)
            .stop(9)
            .deadline_ms(250.0)
            .priority(3)
            .tenant(2)
            .sampling(SamplingParams::seeded(7));
        assert_eq!(r.max_new, 5);
        assert_eq!(r.stop_token, Some(9));
        assert_eq!(r.deadline_ms, Some(250.0));
        assert_eq!(r.priority, 3);
        assert_eq!(r.tenant, 2);
        assert!(matches!(r.sampling, SamplingParams::Seeded { seed: 7, .. }));
    }

    #[test]
    fn events_stream_in_order_and_record_ttft() {
        let stats = collector();
        let (mut h, s) = handle_pair(4, stats.clone());
        assert_eq!(h.id(), 4);
        s.send(Event::Started);
        s.send(Event::Token { pos: 0, tok: 11 });
        s.send(Event::Token { pos: 1, tok: 12 });
        s.send(Event::Done(Completion {
            id: 4,
            tokens: vec![11, 12],
            ttft_ms: Some(1.0),
            total_ms: Some(2.0),
            preemptions: 0,
            cached_prefix_tokens: 0,
        }));
        assert!(matches!(h.try_next(), Some(Event::Started)));
        assert!(matches!(h.try_next(), Some(Event::Token { pos: 0, tok: 11 })));
        assert!(!h.is_terminal());
        assert!(matches!(h.try_next(), Some(Event::Token { pos: 1, tok: 12 })));
        assert!(matches!(h.try_next(), Some(Event::Done(_))));
        assert!(h.is_terminal());
        assert!(h.try_next().is_none());
        assert_eq!(stats.lock().unwrap().count(), 1, "one TTFT sample, on the first token");
    }

    #[test]
    fn cancel_sets_the_shared_flag() {
        let (h, s) = handle_pair(0, collector());
        assert!(!s.cancelled());
        h.cancel();
        assert!(s.cancelled());
        h.cancel(); // idempotent
        assert!(s.cancelled());
    }

    #[test]
    fn wait_returns_failure_reasons() {
        let (mut h, s) = handle_pair(0, collector());
        s.send(Event::Failed(FailReason::Rejected(SubmitError::QueueFull)));
        match h.wait(Duration::from_millis(100)) {
            Err(FailReason::Rejected(SubmitError::QueueFull)) => {}
            other => panic!("unexpected: {other:?}"),
        }
        // disconnected sender -> WorkerDead
        let (mut h, s) = handle_pair(1, collector());
        drop(s);
        assert!(matches!(h.wait(Duration::from_millis(100)), Err(FailReason::WorkerDead)));
        // live sender but nothing arriving -> TimedOut, not WorkerDead
        let (mut h, _s) = handle_pair(2, collector());
        assert!(matches!(h.wait(Duration::from_millis(10)), Err(FailReason::TimedOut)));
    }

    #[test]
    fn detached_session_discards_events() {
        let s = Session::detached();
        s.send(Event::Started); // must not panic
        assert!(!s.cancelled());
    }
}
