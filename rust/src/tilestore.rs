//! Cold-tier spill store for the tiered KV hierarchy (`docs/kv-tiers.md`).
//!
//! A demoted KV tile's *exact* hot-tier payload (the per-head int8 codes
//! for K and V) is serialized once into a [`TileStore`] and never
//! rewritten — records are write-once and immutable, which is what makes
//! demote→promote round trips byte-stable and lets prefix forks share
//! spilled tiles the same way they share quantized blocks (PR 3's
//! no-requantize guarantee).  Keys carry a fork-unique `owner` id so a
//! forked sequence's post-boundary tiles can never collide with its
//! parent's records.
//!
//! Two implementations: [`FileTileStore`] (append-only spill file, the
//! production tier) and [`MemTileStore`] (in-memory test double so tier
//! tests stay hermetic and deterministic).  All I/O failures surface as
//! typed [`TileStoreError`]s — this module never unwraps.

use std::collections::HashMap;
use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Spill-file magic ("KVSP") — first 4 bytes of a [`FileTileStore`] file.
pub const SPILL_MAGIC: [u8; 4] = *b"KVSP";
/// Spill-file format version (second 4 bytes, little-endian).
pub const SPILL_VERSION: u32 = 1;

/// Identifies one spilled tile payload.  `owner` is a fork-unique id
/// handed out by [`TileStore::alloc_owner`]: a cache clone (prefix fork,
/// snapshot) and a truncation both refresh their owner so tiles written
/// *after* the divergence point get fresh keys, while inherited tiles
/// keep the owner they were first spilled under and stay shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileKey {
    pub owner: u32,
    pub layer: u32,
    pub tile: u32,
}

impl fmt::Display for TileKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(owner {}, layer {}, tile {})", self.owner, self.layer, self.tile)
    }
}

/// Typed spill-tier failure: I/O, a key that was never stored, or a
/// malformed spill file.
#[derive(Debug)]
pub enum TileStoreError {
    Io(std::io::Error),
    Missing(TileKey),
    Corrupt(String),
}

impl fmt::Display for TileStoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TileStoreError::Io(e) => write!(f, "tile store I/O error: {e}"),
            TileStoreError::Missing(k) => write!(f, "tile store has no record for {k}"),
            TileStoreError::Corrupt(msg) => write!(f, "tile store corrupt: {msg}"),
        }
    }
}

impl std::error::Error for TileStoreError {}

impl From<std::io::Error> for TileStoreError {
    fn from(e: std::io::Error) -> Self {
        TileStoreError::Io(e)
    }
}

/// Cold-tier storage of demoted tile payloads.  `Send` so one store can
/// be shared (behind `Arc<Mutex<..>>`) across the engine's sequences and
/// the worker pool's policy-phase jobs.
pub trait TileStore: Send {
    /// Persist `payload` under `key`.  Records are write-once and
    /// immutable: if the key already exists the call is a no-op — by the
    /// byte-stability invariant a re-demoted tile's payload is identical
    /// to the bytes already stored.
    fn put(&mut self, key: TileKey, payload: &[u8]) -> Result<(), TileStoreError>;

    /// Read the payload stored under `key` into `out` (replacing its
    /// contents).  [`TileStoreError::Missing`] if the key was never put.
    fn get(&mut self, key: TileKey, out: &mut Vec<u8>) -> Result<(), TileStoreError>;

    /// Whether a record exists for `key`.
    fn contains(&self, key: TileKey) -> bool;

    /// Number of stored records.
    fn records(&self) -> usize;

    /// Total payload bytes stored (excluding per-record framing).
    fn payload_bytes(&self) -> usize;

    /// Hand out a fresh, store-unique owner id (see [`TileKey`]).
    fn alloc_owner(&mut self) -> u32;
}

/// The shared handle tiered caches hold: one store per engine, shared
/// across every sequence (and its prefix forks).
pub type SharedTileStore = Arc<Mutex<Box<dyn TileStore>>>;

/// Wrap a store implementation into the shared handle type.
pub fn shared_store(store: impl TileStore + 'static) -> SharedTileStore {
    Arc::new(Mutex::new(Box::new(store)))
}

/// Promotion/demotion accounting, drained per tick into `ServeMetrics`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TierStats {
    /// Tiles restored into the hot arena (planned prefetch + demand).
    pub tiles_promoted: u64,
    /// Tiles evicted from the hot arena (spilled on first demotion).
    pub tiles_demoted: u64,
    /// Needed tiles that were already hot when the kernels asked —
    /// i.e. the tick-boundary prefetch staged them in time.
    pub prefetch_hits: u64,
    /// Needed tiles that had to be demand-promoted inside the policy
    /// phase because no hint staged them.
    pub prefetch_misses: u64,
}

impl TierStats {
    pub fn merge(&mut self, o: &TierStats) {
        self.tiles_promoted += o.tiles_promoted;
        self.tiles_demoted += o.tiles_demoted;
        self.prefetch_hits += o.prefetch_hits;
        self.prefetch_misses += o.prefetch_misses;
    }

    pub fn is_zero(&self) -> bool {
        *self == TierStats::default()
    }
}

/// Per-cache tier sizing knobs (see `ServeConfig::{kv_tiers,
/// hot_tile_budget}` and `docs/kv-tiers.md`).
#[derive(Debug, Clone, Copy)]
pub struct TierParams {
    /// Max completed tiles resident in one tiered layer's hot arena.
    /// Demand promotion may overshoot this transiently (correctness
    /// first); planned maintenance trims back to it.
    pub hot_tile_budget: usize,
    /// Max demoted tiles keeping a packed-int4 warm shadow in RAM;
    /// older warm tiles drop to cold (spill record only).
    pub warm_tile_budget: usize,
}

impl TierParams {
    pub fn new(hot_tile_budget: usize) -> Self {
        Self { hot_tile_budget: hot_tile_budget.max(1), warm_tile_budget: hot_tile_budget.max(1) }
    }
}

// ---------------------------------------------------------------------------
// In-memory test double
// ---------------------------------------------------------------------------

/// Hermetic in-memory [`TileStore`] for tests: same write-once contract
/// as the file store, no filesystem.
#[derive(Default)]
pub struct MemTileStore {
    // keyed lookups only — never iterated, so the HashMap cannot leak
    // nondeterminism into anything observable
    map: HashMap<TileKey, Vec<u8>>,
    bytes: usize,
    next_owner: u32,
}

impl MemTileStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl TileStore for MemTileStore {
    fn put(&mut self, key: TileKey, payload: &[u8]) -> Result<(), TileStoreError> {
        if !self.map.contains_key(&key) {
            self.bytes += payload.len();
            self.map.insert(key, payload.to_vec());
        }
        Ok(())
    }

    fn get(&mut self, key: TileKey, out: &mut Vec<u8>) -> Result<(), TileStoreError> {
        let Some(p) = self.map.get(&key) else {
            return Err(TileStoreError::Missing(key));
        };
        out.clear();
        out.extend_from_slice(p);
        Ok(())
    }

    fn contains(&self, key: TileKey) -> bool {
        self.map.contains_key(&key)
    }

    fn records(&self) -> usize {
        self.map.len()
    }

    fn payload_bytes(&self) -> usize {
        self.bytes
    }

    fn alloc_owner(&mut self) -> u32 {
        self.next_owner += 1;
        self.next_owner
    }
}

// ---------------------------------------------------------------------------
// File-backed spill store
// ---------------------------------------------------------------------------

/// Append-only file-backed [`TileStore`].
///
/// On-disk format (all integers little-endian):
///
/// ```text
/// header:  magic "KVSP" (4 bytes) | version u32
/// record:  owner u32 | layer u32 | tile u32 | payload_len u32 | payload
/// ```
///
/// Records are only ever appended; the in-RAM index maps keys to file
/// offsets.  Opening an existing file replays the records to rebuild the
/// index (and the next owner id), erroring with
/// [`TileStoreError::Corrupt`] on a bad magic/version, a truncated
/// record, or a duplicate key (write-once means duplicates cannot occur
/// in a well-formed file).
pub struct FileTileStore {
    file: File,
    path: PathBuf,
    index: HashMap<TileKey, (u64, u32)>,
    end: u64,
    bytes: usize,
    next_owner: u32,
}

const REC_HEADER: usize = 16;

fn u32le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

impl FileTileStore {
    /// Create (or reopen and replay) the spill file at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<Self, TileStoreError> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).truncate(false).open(&path)?;
        let flen = file.metadata()?.len();
        let mut store = Self {
            file,
            path,
            index: HashMap::new(),
            end: 0,
            bytes: 0,
            next_owner: 0,
        };
        if flen == 0 {
            let mut header = [0u8; 8];
            header[..4].copy_from_slice(&SPILL_MAGIC);
            header[4..].copy_from_slice(&SPILL_VERSION.to_le_bytes());
            store.file.write_all(&header)?;
            store.end = 8;
            return Ok(store);
        }
        store.replay(flen)?;
        Ok(store)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Rebuild the index from an existing spill file of length `flen`.
    fn replay(&mut self, flen: u64) -> Result<(), TileStoreError> {
        self.file.seek(SeekFrom::Start(0))?;
        let mut header = [0u8; 8];
        if flen < 8 {
            return Err(TileStoreError::Corrupt(format!(
                "spill file {} shorter than its header",
                self.path.display()
            )));
        }
        self.file.read_exact(&mut header)?;
        if header[..4] != SPILL_MAGIC {
            return Err(TileStoreError::Corrupt(format!(
                "bad magic in spill file {}",
                self.path.display()
            )));
        }
        let version = u32le(&header[4..8]);
        if version != SPILL_VERSION {
            return Err(TileStoreError::Corrupt(format!(
                "spill file {} has version {version}, expected {SPILL_VERSION}",
                self.path.display()
            )));
        }
        let mut off = 8u64;
        let mut rec = [0u8; REC_HEADER];
        while off < flen {
            if off + REC_HEADER as u64 > flen {
                return Err(TileStoreError::Corrupt(format!(
                    "truncated record header at offset {off} in {}",
                    self.path.display()
                )));
            }
            self.file.read_exact(&mut rec)?;
            let key = TileKey {
                owner: u32le(&rec[0..4]),
                layer: u32le(&rec[4..8]),
                tile: u32le(&rec[8..12]),
            };
            let len = u32le(&rec[12..16]);
            let payload_at = off + REC_HEADER as u64;
            if payload_at + len as u64 > flen {
                return Err(TileStoreError::Corrupt(format!(
                    "truncated payload for {key} at offset {off} in {}",
                    self.path.display()
                )));
            }
            if self.index.insert(key, (payload_at, len)).is_some() {
                return Err(TileStoreError::Corrupt(format!(
                    "duplicate record for {key} in {} (records are write-once)",
                    self.path.display()
                )));
            }
            self.bytes += len as usize;
            self.next_owner = self.next_owner.max(key.owner);
            off = payload_at + len as u64;
            self.file.seek(SeekFrom::Start(off))?;
        }
        self.end = flen;
        Ok(())
    }
}

impl TileStore for FileTileStore {
    fn put(&mut self, key: TileKey, payload: &[u8]) -> Result<(), TileStoreError> {
        if self.index.contains_key(&key) {
            return Ok(());
        }
        let len = u32::try_from(payload.len()).map_err(|_| {
            TileStoreError::Corrupt(format!("tile payload of {} bytes overflows u32", payload.len()))
        })?;
        let mut rec = [0u8; REC_HEADER];
        rec[0..4].copy_from_slice(&key.owner.to_le_bytes());
        rec[4..8].copy_from_slice(&key.layer.to_le_bytes());
        rec[8..12].copy_from_slice(&key.tile.to_le_bytes());
        rec[12..16].copy_from_slice(&len.to_le_bytes());
        self.file.seek(SeekFrom::Start(self.end))?;
        self.file.write_all(&rec)?;
        self.file.write_all(payload)?;
        self.index.insert(key, (self.end + REC_HEADER as u64, len));
        self.end += (REC_HEADER + payload.len()) as u64;
        self.bytes += payload.len();
        Ok(())
    }

    fn get(&mut self, key: TileKey, out: &mut Vec<u8>) -> Result<(), TileStoreError> {
        let Some(&(off, len)) = self.index.get(&key) else {
            return Err(TileStoreError::Missing(key));
        };
        out.clear();
        out.resize(len as usize, 0);
        self.file.seek(SeekFrom::Start(off))?;
        self.file.read_exact(out).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                TileStoreError::Corrupt(format!(
                    "short read for {key} in {}",
                    self.path.display()
                ))
            } else {
                TileStoreError::Io(e)
            }
        })?;
        Ok(())
    }

    fn contains(&self, key: TileKey) -> bool {
        self.index.contains_key(&key)
    }

    fn records(&self) -> usize {
        self.index.len()
    }

    fn payload_bytes(&self) -> usize {
        self.bytes
    }

    fn alloc_owner(&mut self) -> u32 {
        self.next_owner += 1;
        self.next_owner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(owner: u32, layer: u32, tile: u32) -> TileKey {
        TileKey { owner, layer, tile }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kascade_tilestore_{}_{name}", std::process::id()))
    }

    fn exercise_store(store: &mut dyn TileStore) {
        let a = key(1, 0, 7);
        let b = key(1, 2, 7);
        store.put(a, &[1, 2, 3, 4]).unwrap();
        store.put(b, &[9, 8]).unwrap();
        assert!(store.contains(a) && store.contains(b));
        assert_eq!(store.records(), 2);
        assert_eq!(store.payload_bytes(), 6);
        let mut out = Vec::new();
        store.get(a, &mut out).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
        // write-once: a second put under the same key is a no-op
        store.put(a, &[0xFF; 4]).unwrap();
        store.get(a, &mut out).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert_eq!(store.records(), 2);
        // missing key is a typed error
        match store.get(key(2, 0, 7), &mut out) {
            Err(TileStoreError::Missing(k)) => assert_eq!(k, key(2, 0, 7)),
            other => panic!("expected Missing, got {other:?}"),
        }
        // owner ids are unique and monotone
        let o1 = store.alloc_owner();
        let o2 = store.alloc_owner();
        assert!(o2 > o1);
    }

    #[test]
    fn mem_store_contract() {
        let mut s = MemTileStore::new();
        exercise_store(&mut s);
    }

    #[test]
    fn file_store_contract_and_reopen_replay() {
        let path = tmp_path("contract.kvsp");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = FileTileStore::open(&path).unwrap();
            exercise_store(&mut s);
            s.put(key(3, 1, 0), &[7; 32]).unwrap();
        }
        // reopen: index and owner counter replay from the records
        let mut s = FileTileStore::open(&path).unwrap();
        assert_eq!(s.records(), 3);
        assert_eq!(s.payload_bytes(), 6 + 32);
        let mut out = Vec::new();
        s.get(key(1, 0, 7), &mut out).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
        s.get(key(3, 1, 0), &mut out).unwrap();
        assert_eq!(out, vec![7; 32]);
        assert!(s.alloc_owner() > 3, "owner counter resumes past replayed owners");
        // appends after a replay still round-trip
        s.put(key(4, 0, 1), &[5, 6]).unwrap();
        s.get(key(4, 0, 1), &mut out).unwrap();
        assert_eq!(out, vec![5, 6]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn file_store_rejects_bad_magic_and_truncation() {
        let path = tmp_path("corrupt.kvsp");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        match FileTileStore::open(&path) {
            Err(TileStoreError::Corrupt(msg)) => assert!(msg.contains("magic"), "{msg}"),
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
        let _ = std::fs::remove_file(&path);

        let path = tmp_path("truncated.kvsp");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = FileTileStore::open(&path).unwrap();
            s.put(key(1, 0, 0), &[1; 64]).unwrap();
        }
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 10]).unwrap();
        match FileTileStore::open(&path) {
            Err(TileStoreError::Corrupt(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shared_handle_is_send_and_clonable() {
        let store = shared_store(MemTileStore::new());
        let s2 = store.clone();
        let t = std::thread::spawn(move || {
            let mut guard = s2.lock().unwrap();
            guard.put(key(1, 0, 0), &[1, 2]).unwrap();
        });
        t.join().unwrap();
        let mut out = Vec::new();
        store.lock().unwrap().get(key(1, 0, 0), &mut out).unwrap();
        assert_eq!(out, vec![1, 2]);
    }
}
