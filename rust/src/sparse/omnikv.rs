//! OmniKV-like baseline (Hao et al., 2025): a few manually chosen *filter*
//! layers pick a context-token subset (shared across all heads) which the
//! following layers attend to.  The subset is refreshed only every
//! `refresh_every` decode steps (OmniKV's chunked reselection — it targets
//! KV offload, so reselection is deliberately infrequent).  Decode-only.

use super::{Selection, SparsePolicy};
use crate::attention::{self, AttnScratch, CostTracker, KvCache};
use crate::config::TopKRule;

pub struct OmniKvPolicy {
    pub filter_layers: Vec<usize>,
    pub rule: TopKRule,
    pub refresh_every: usize,
    /// shared index set selected at each filter layer
    selected: Vec<Option<Vec<u32>>>,
    /// reused all-heads pooled distribution
    all: Vec<f32>,
    step: usize,
    n_layers: usize,
}

impl OmniKvPolicy {
    pub fn new(n_layers: usize, filter_layers: Vec<usize>, rule: TopKRule) -> Self {
        Self {
            filter_layers,
            rule,
            refresh_every: 16,
            selected: vec![None; n_layers],
            all: Vec::new(),
            step: 0,
            n_layers,
        }
    }

    fn filter_of(&self, layer: usize) -> Option<usize> {
        self.filter_layers.iter().rev().find(|&&f| f <= layer).copied()
    }
}

impl SparsePolicy for OmniKvPolicy {
    fn name(&self) -> String {
        "omnikv".into()
    }

    fn reset(&mut self) {
        self.selected = vec![None; self.n_layers];
        self.step = 0;
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        if layer == 0 {
            self.step += 1; // count decode steps at layer 0
        }
        let k = self.rule.k(cache.len);
        if k >= cache.len {
            return Selection::Dense;
        }
        if self.filter_layers.contains(&layer) {
            let stale = self.selected[layer].is_none()
                || (self.step - 1) % self.refresh_every == 0;
            if stale {
                // pool across all heads -> one shared set
                attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
                super::pool_all_into(&scratch.planes, &mut self.all);
                cost.topk_items += self.all.len() as u64;
                self.selected[layer] = Some(crate::tensor::topk_indices(&self.all, k));
            }
            // filter layers themselves attend densely (they must see the
            // full context to filter it)
            return Selection::Dense;
        }
        match self.filter_of(layer).and_then(|f| self.selected[f].as_ref()) {
            Some(idx) => {
                super::broadcast_into(idx, cache.n_kv, &mut scratch.sel);
                Selection::Sparse
            }
            None => Selection::Dense,
        }
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        let mut p = OmniKvPolicy::new(self.n_layers, self.filter_layers.clone(), self.rule);
        p.refresh_every = self.refresh_every;
        Some(Box::new(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Selection;
    use crate::tensor::Rng;

    fn setup() -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(9);
        let (n_kv, g, d, len) = (2, 2, 16, 512);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(n_kv, d, len);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        (q, c)
    }

    #[test]
    fn filter_layer_selects_then_following_layers_reuse() {
        let (q, c) = setup();
        let mut pol = OmniKvPolicy::new(8, vec![0, 4], TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scratch = crate::attention::AttnScratch::new();
        assert_eq!(pol.decode(0, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        assert_eq!(pol.decode(1, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel.head(0), scratch.sel.head(1), "shared across heads");
        assert_eq!(scratch.sel.head(0).len(), 51);
        let sel1 = scratch.sel.clone();
        // layers 1..3 share filter 0's set; layer 5 uses filter 4's
        assert_eq!(pol.decode(3, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel, sel1);
    }

    /// OmniKV's filter-layer selection over an int8 cache (fused pooled
    /// scoring) must pick the same shared set as over f32 when the
    /// planted scores have margin.
    #[test]
    fn int8_cache_selects_same_filter_set() {
        use crate::config::KvDtype;
        let mut r = Rng::new(62);
        let (n_kv, g, d, len) = (2, 2, 16, 256);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cf = KvCache::new(n_kv, d, len);
        let mut cq = KvCache::with_opts(n_kv, d, len, 16, KvDtype::Int8);
        let strong: Vec<usize> = (0..25).map(|i| i * 10 + 4).collect();
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.05);
            r.fill_normal(&mut v, 1.0);
            if strong.contains(&p) {
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        let mk = || OmniKvPolicy::new(4, vec![0], TopKRule::new(0.1, 16));
        let (mut pf, mut pq) = (mk(), mk());
        let mut cost = CostTracker::default();
        let mut scr_f = crate::attention::AttnScratch::new();
        let mut scr_q = crate::attention::AttnScratch::new();
        pf.decode(0, &q, &cf, 2, &mut scr_f, &mut cost);
        pq.decode(0, &q, &cq, 2, &mut scr_q, &mut cost);
        let sf = pf.decode(1, &q, &cf, 2, &mut scr_f, &mut cost);
        let sq = pq.decode(1, &q, &cq, 2, &mut scr_q, &mut cost);
        assert_eq!((sf, sq), (Selection::Sparse, Selection::Sparse));
        let mut sa = scr_f.sel.head(0).to_vec();
        let mut sb = scr_q.sel.head(0).to_vec();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb, "filter selection diverged between storage modes");
    }

    #[test]
    fn refresh_cadence() {
        let (q, c) = setup();
        let mut pol = OmniKvPolicy::new(4, vec![0], TopKRule::new(0.1, 16));
        pol.refresh_every = 4;
        let mut cost = CostTracker::default();
        let mut scratch = crate::attention::AttnScratch::new();
        pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        let reads1 = cost.score_key_reads;
        assert!(reads1 > 0);
        // steps 2..4: no rescoring
        for _ in 0..3 {
            pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        }
        assert_eq!(cost.score_key_reads, reads1);
        // step 5: refresh
        pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        assert!(cost.score_key_reads > reads1);
    }
}
