//! Quest baseline (Tang et al., 2024): query-aware page-level sparsity.
//!
//! Keys are summarized per page by elementwise min/max; for a query q the
//! upper bound of any score in the page is sum_d max(q_d * min_d,
//! q_d * max_d).  The pages with the highest bounds are attended densely.
//! Decode-only (prefill stays dense), and the first two layers run dense,
//! as in the original system.

use super::{Selection, SparsePolicy};
use crate::attention::{AttnScratch, CostTracker, KvCache};
use crate::config::TopKRule;

pub struct QuestPolicy {
    pub rule: TopKRule,
    pub dense_layers: usize,
    /// reused per-head page-bound buffer
    bounds: Vec<f32>,
}

impl QuestPolicy {
    pub fn new(rule: TopKRule) -> Self {
        Self { rule, dense_layers: 2, bounds: Vec::new() }
    }

    /// Upper-bound score of page `page` for kv head `h` under the group's
    /// query rows (max over the group, as all of them will read the page).
    fn page_bound(q: &[f32], cache: &KvCache, h: usize, g: usize, page: usize) -> f32 {
        let d = cache.d;
        let (mins, maxs) = cache.page_summary(h, page);
        let mut best = f32::NEG_INFINITY;
        for qi in 0..g {
            let qrow = &q[(h * g + qi) * d..(h * g + qi + 1) * d];
            let mut ub = 0.0;
            for i in 0..d {
                ub += (qrow[i] * mins[i]).max(qrow[i] * maxs[i]);
            }
            best = best.max(ub);
        }
        best
    }
}

impl SparsePolicy for QuestPolicy {
    fn name(&self) -> String {
        "quest".into()
    }

    fn reset(&mut self) {}

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        if layer < self.dense_layers {
            return Selection::Dense;
        }
        let len = cache.len;
        let k = self.rule.k(len);
        if k >= len {
            return Selection::Dense;
        }
        let ps = cache.page_size();
        let n_pages = cache.n_pages();
        let budget_pages = k.div_ceil(ps);
        if budget_pages >= n_pages {
            return Selection::Dense;
        }
        let sel = &mut scratch.sel;
        sel.clear();
        for h in 0..cache.n_kv {
            self.bounds.clear();
            self.bounds.extend((0..n_pages).map(|p| Self::page_bound(q, cache, h, g, p)));
            cost.score_key_reads += (2 * n_pages * g) as u64; // min+max rows
            cost.topk_items += n_pages as u64;
            let pages = crate::tensor::topk_indices(&self.bounds, budget_pages);
            for &p in &pages {
                let lo = p as usize * ps;
                let hi = ((p as usize + 1) * ps).min(len);
                for pos in lo as u32..hi as u32 {
                    sel.push(pos);
                }
            }
            sel.close_head();
        }
        Selection::Sparse
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(QuestPolicy {
            rule: self.rule,
            dense_layers: self.dense_layers,
            bounds: Vec::new(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn selects_the_page_containing_an_aligned_key() {
        let mut r = Rng::new(6);
        let (n_kv, g, d, len) = (2, 2, 16, 256);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len);
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.2);
            r.fill_normal(&mut v, 1.0);
            if p == 133 {
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 3.0;
                    }
                }
            }
            cache.push(&k, &v);
        }
        let mut pol = QuestPolicy::new(TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(pol.decode(2, &q, &cache, g, &mut scratch, &mut cost), Selection::Sparse);
        for h in 0..n_kv {
            assert!(scratch.sel.head(h).contains(&133), "page of key 133 not selected");
        }
    }

    /// Quest scores pages from the f32 min/max summaries, which int8
    /// caches keep exact (summaries are computed from the raw keys at
    /// push time) — page selection is identical across storage modes.
    #[test]
    fn int8_cache_selects_identical_pages() {
        use crate::config::KvDtype;
        let mut r = Rng::new(61);
        let (n_kv, g, d, len) = (2, 2, 16, 256);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cf = KvCache::new(n_kv, d, len);
        let mut cq = KvCache::with_opts(n_kv, d, len, 16, KvDtype::Int8);
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.2);
            r.fill_normal(&mut v, 1.0);
            if p == 133 {
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 3.0;
                    }
                }
            }
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        let mut pol = QuestPolicy::new(TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scr_f = AttnScratch::new();
        let mut scr_q = AttnScratch::new();
        let sf = pol.decode(2, &q, &cf, g, &mut scr_f, &mut cost);
        let sq = pol.decode(2, &q, &cq, g, &mut scr_q, &mut cost);
        assert_eq!(sf, sq);
        assert_eq!(scr_f.sel, scr_q.sel, "page selection must not depend on KV storage mode");
    }

    #[test]
    fn early_layers_dense_and_prefill_dense() {
        let mut r = Rng::new(7);
        let mut q = vec![0.0; 2 * 2 * 16];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(2, 16, 256);
        let k = vec![0.1; 32];
        for _ in 0..256 {
            cache.push(&k, &k);
        }
        let mut pol = QuestPolicy::new(TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(pol.decode(0, &q, &cache, 2, &mut scratch, &mut cost), Selection::Dense);
        assert_eq!(pol.decode(1, &q, &cache, 2, &mut scratch, &mut cost), Selection::Dense);
        assert!(!pol.sparse_prefill());
    }

    #[test]
    fn page_granularity_indices_are_contiguous_runs() {
        let mut r = Rng::new(8);
        let mut q = vec![0.0; 2 * 2 * 16];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(2, 16, 512);
        for _ in 0..512 {
            let mut k = vec![0.0; 32];
            r.fill_normal(&mut k, 0.5);
            cache.push(&k, &k);
        }
        let mut pol = QuestPolicy::new(TopKRule::new(0.1, 32));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(pol.decode(3, &q, &cache, 2, &mut scratch, &mut cost), Selection::Sparse);
        let ps = cache.page_size();
        for hi in 0..scratch.sel.n_heads() {
            let h = scratch.sel.head(hi);
            assert_eq!(h.len() % ps, 0);
            for chunk in h.chunks(ps) {
                for w in chunk.windows(2) {
                    assert_eq!(w[1], w[0] + 1);
                }
                assert_eq!(chunk[0] as usize % ps, 0);
            }
        }
    }
}
