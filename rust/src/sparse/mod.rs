//! Sparse-attention policies: Kascade and every baseline the paper
//! compares against (Tables 1-2), behind one trait the native engine and
//! the coordinator both drive.
//!
//! A policy decides, per layer (and per prefill Q-tile), whether attention
//! runs dense or over an explicit per-KV-head index set.  Policies that
//! need attention scores (anchor layers, oracles) compute them through the
//! engine's pooled-score helpers so their cost is accounted like any other
//! attention work.
//!
//! Selections flow through the per-sequence [`AttnScratch`] arena rather
//! than freshly allocated `Vec<Vec<u32>>`s: a policy that goes sparse
//! writes its per-KV-head indices into `scratch.sel` (an [`IndexSet`]
//! whose buffers keep their capacity across steps) and returns the
//! [`Selection::Sparse`] marker — the steady-state decode loop performs
//! no heap allocations through this path (see `docs/perf.md`).

pub mod kascade_policy;
pub mod lessismore;
pub mod omnikv;
pub mod quest;
pub mod streaming;

pub use kascade_policy::{KascadeAllPooledPolicy, KascadePolicy};
pub use lessismore::LessIsMorePolicy;
pub use omnikv::OmniKvPolicy;
pub use quest::QuestPolicy;
pub use streaming::StreamingLlmPolicy;

use crate::attention::{self, AttnScratch, CostTracker, IndexSet, KvCache};
use crate::config::TopKRule;

/// Per-layer attention decision.  `Sparse` is a marker: the actual
/// per-KV-head indices live in the `AttnScratch::sel` the policy was
/// handed (exactly `cache.n_kv` closed heads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Full attention over the whole context.
    Dense,
    /// Sparse attention over the index sets written to `scratch.sel`.
    Sparse,
}

impl Selection {
    /// Keys touched per KV head (dense -> `len * n_kv`), given the
    /// selection's index set.
    pub fn cost_keys(&self, sel: &IndexSet, len: usize, n_kv: usize) -> usize {
        match self {
            Selection::Dense => len * n_kv,
            Selection::Sparse => sel.total(),
        }
    }
}

/// A training-free sparse attention strategy.
pub trait SparsePolicy: Send {
    fn name(&self) -> String;

    /// Clear per-sequence state (index caches etc.).
    fn reset(&mut self);

    /// Decode-time decision for `layer`.  `q` is `[n_q * d]` head-major.
    /// On [`Selection::Sparse`] the policy must have filled
    /// `scratch.sel` with one closed head per KV head; it may also use
    /// `scratch.planes` freely for score computation.
    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection;

    /// Prefill-time decision for Q-tile `tile` of `layer` whose first query
    /// sits at absolute position `start`.  `qs` is `[tile_len, n_q * d]`.
    /// Same `scratch.sel` contract as [`SparsePolicy::decode`].
    /// Default: dense prefill (what Quest / OmniKV / LessIsMore do — the
    /// paper notes they only optimize decode).
    fn prefill_tile(
        &mut self,
        _layer: usize,
        _tile: usize,
        _start: usize,
        _qs: &[f32],
        _cache: &KvCache,
        _g: usize,
        _scratch: &mut AttnScratch,
        _cost: &mut CostTracker,
    ) -> Selection {
        Selection::Dense
    }

    /// Whether the policy sparsifies prefill at all (used by experiment
    /// drivers to share a single dense prefill across baselines).
    fn sparse_prefill(&self) -> bool {
        false
    }

    /// Whether `layer`'s decode decision may score *any* stored position
    /// (dense fallbacks, anchor/estimation passes, page-bound scans).
    /// KV tiering (`docs/kv-tiers.md`) only bounds the hot set of layers
    /// where this is `false` — layers whose index sets are computed
    /// elsewhere (Kascade reuse layers) — so the conservative default
    /// keeps every cache fully resident.
    fn scans_all_positions(&self, _layer: usize) -> bool {
        true
    }

    /// Write the tiles (position / `page_size`) the policy's upcoming
    /// sparse layers will touch — sorted, deduplicated — into `out`, and
    /// return true.  The default (false, `out` untouched) means "no
    /// hint": the tier planner then leaves residency to demand
    /// promotion.  Kascade overrides this with the union of its cached
    /// anchor-layer Top-k selections, which is exactly the set every
    /// reuse layer scores until the anchors re-select
    /// (`docs/kv-tiers.md`, "needed_tiles hint protocol").
    fn needed_tiles(&self, _page_size: usize, _out: &mut Vec<u32>) -> bool {
        false
    }

    /// Fork a fresh policy with the same configuration but cleared
    /// per-sequence state.  Powers prefix-cache snapshots: KV blocks are
    /// shared across sequences, but Top-k index state (anchor-layer
    /// selections, reuse-layer caches) is per-sequence and must NOT leak
    /// through a shared snapshot — the resumed sequence rebuilds its own.
    /// `None` disables prefix-cache compute reuse for backends driven by
    /// this policy.
    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        None
    }
}

/// Always-dense baseline.
pub struct DensePolicy;

impl SparsePolicy for DensePolicy {
    fn name(&self) -> String {
        "dense".into()
    }

    fn reset(&mut self) {}

    fn decode(
        &mut self,
        _: usize,
        _: &[f32],
        _: &KvCache,
        _: usize,
        _: &mut AttnScratch,
        _: &mut CostTracker,
    ) -> Selection {
        Selection::Dense
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(DensePolicy))
    }
}

/// Oracle Top-k (Sec. 3.1): exact per-layer Top-k from this layer's own
/// pooled post-softmax scores.  An accuracy upper bound, not a deployable
/// policy (it pays full score cost every layer).
pub struct OraclePolicy {
    pub rule: TopKRule,
    /// Layer 0 stays dense (paper always keeps layer 0 dense).
    pub layer0_dense: bool,
}

impl OraclePolicy {
    pub fn new(rule: TopKRule) -> Self {
        Self { rule, layer0_dense: true }
    }
}

impl SparsePolicy for OraclePolicy {
    fn name(&self) -> String {
        format!("oracle-top{:.3}", self.rule.frac)
    }

    fn reset(&mut self) {}

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        if layer == 0 && self.layer0_dense {
            return Selection::Dense;
        }
        let k = self.rule.k(cache.len);
        if k >= cache.len {
            return Selection::Dense;
        }
        attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
        attention::select_topk(scratch, k, cost);
        Selection::Sparse
    }

    fn prefill_tile(
        &mut self,
        layer: usize,
        _tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        if layer == 0 && self.layer0_dense {
            return Selection::Dense;
        }
        let n_q = cache.n_kv * g;
        let tile_len = qs.len() / (n_q * cache.d);
        let kv_len = start + tile_len;
        let k = self.rule.k(kv_len);
        if k >= kv_len {
            return Selection::Dense;
        }
        attention::prefill_pooled_scores(qs, start, cache, g, &mut scratch.planes, cost);
        attention::select_topk(scratch, k, cost);
        Selection::Sparse
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(OraclePolicy { rule: self.rule, layer0_dense: self.layer0_dense }))
    }
}

/// Mean-pool the `[n_kv, len]` pooled planes into one shared
/// distribution (the "all heads pooled" / filter-layer statistic used by
/// the OmniKV, LessIsMore and Kascade-ablation baselines), reusing the
/// caller's buffer.
pub(crate) fn pool_all_into(planes: &crate::attention::ScorePlanes, all: &mut Vec<f32>) {
    let (hn, len) = (planes.pooled_heads(), planes.pooled_len());
    all.clear();
    all.resize(len, 0.0);
    let inv = 1.0 / hn as f32;
    for h in 0..hn {
        for (o, &x) in all.iter_mut().zip(planes.pooled_head(h)) {
            *o += x * inv;
        }
    }
}

/// Broadcast one shared index set to every KV head of `sel`.
pub(crate) fn broadcast_into(idx: &[u32], n_kv: usize, sel: &mut IndexSet) {
    sel.clear();
    for _ in 0..n_kv {
        sel.extend_head(idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn cache_with(len: usize) -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(2);
        let (n_kv, g, d) = (2, 2, 16);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(n_kv, d, len);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        (q, c)
    }

    #[test]
    fn dense_policy_always_dense() {
        let (q, c) = cache_with(64);
        let mut p = DensePolicy;
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        for l in 0..8 {
            assert_eq!(p.decode(l, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        }
    }

    #[test]
    fn oracle_respects_layer0_and_k_rule() {
        let (q, c) = cache_with(512);
        let mut p = OraclePolicy::new(TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(p.decode(0, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        assert_eq!(p.decode(1, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel.n_heads(), 2);
        for h in 0..2 {
            assert_eq!(scratch.sel.head(h).len(), 51); // 10% of 512
        }
    }

    #[test]
    fn oracle_falls_back_to_dense_when_k_covers_context() {
        let (q, c) = cache_with(64); // min_k = 128 > 64
        let mut p = OraclePolicy::new(TopKRule::default());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(p.decode(3, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
    }

    #[test]
    fn selection_cost_keys() {
        let empty = IndexSet::new();
        assert_eq!(Selection::Dense.cost_keys(&empty, 100, 4), 400);
        let s = IndexSet::from_nested(&[vec![1, 2], vec![3]]);
        assert_eq!(Selection::Sparse.cost_keys(&s, 100, 2), 3);
    }
}
