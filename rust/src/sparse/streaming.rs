//! StreamingLLM baseline (Xiao et al., 2023): attention sinks + sliding
//! window.  Paper setting (Sec. 4.1): window = 30% of context, 4 sinks.
//! Applies to both prefill and decode (it is a fixed pattern).

use super::{Selection, SparsePolicy};
use crate::attention::{AttnScratch, CostTracker, IndexSet, KvCache};

pub struct StreamingLlmPolicy {
    pub window_frac: f32,
    pub sinks: usize,
}

impl StreamingLlmPolicy {
    pub fn paper_default() -> Self {
        Self { window_frac: 0.30, sinks: 4 }
    }

    /// Sinks + trailing window over a context of `len`, as seen from a
    /// query at position `qpos` (inclusive), written into `sel`.
    fn indices_into(&self, qpos: usize, n_kv: usize, sel: &mut IndexSet) -> Selection {
        let visible = qpos + 1;
        let window = ((visible as f32 * self.window_frac) as usize).max(1);
        if self.sinks + window >= visible {
            return Selection::Dense;
        }
        sel.clear();
        for _ in 0..n_kv {
            for s in 0..self.sinks as u32 {
                sel.push(s);
            }
            for p in (visible - window) as u32..visible as u32 {
                sel.push(p);
            }
            sel.close_head();
        }
        Selection::Sparse
    }
}

impl SparsePolicy for StreamingLlmPolicy {
    fn name(&self) -> String {
        format!("streaming-llm-w{:.0}%", self.window_frac * 100.0)
    }

    fn reset(&mut self) {}

    fn decode(
        &mut self,
        _layer: usize,
        _q: &[f32],
        cache: &KvCache,
        _g: usize,
        scratch: &mut AttnScratch,
        _cost: &mut CostTracker,
    ) -> Selection {
        self.indices_into(cache.len.saturating_sub(1), cache.n_kv, &mut scratch.sel)
    }

    fn prefill_tile(
        &mut self,
        _layer: usize,
        _tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        _cost: &mut CostTracker,
    ) -> Selection {
        // one shared set per tile (computed at the tile's last query; the
        // engine clamps per-query causality)
        let n_q = cache.n_kv * g;
        let tile_len = qs.len() / (n_q * cache.d);
        self.indices_into(start + tile_len - 1, cache.n_kv, &mut scratch.sel)
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(StreamingLlmPolicy { window_frac: self.window_frac, sinks: self.sinks }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn indices(p: &StreamingLlmPolicy, qpos: usize, n_kv: usize) -> (Selection, IndexSet) {
        let mut sel = IndexSet::new();
        let s = p.indices_into(qpos, n_kv, &mut sel);
        (s, sel)
    }

    #[test]
    fn window_plus_sinks() {
        let p = StreamingLlmPolicy::paper_default();
        let (s, sel) = indices(&p, 999, 2);
        assert_eq!(s, Selection::Sparse);
        assert_eq!(sel.n_heads(), 2);
        let h = sel.head(0);
        assert_eq!(&h[..4], &[0, 1, 2, 3]);
        assert_eq!(*h.last().unwrap(), 999);
        assert_eq!(h.len(), 4 + 300);
    }

    #[test]
    fn short_context_is_dense() {
        let p = StreamingLlmPolicy::paper_default();
        // visible(4) <= sinks + window(1): everything is covered anyway
        assert_eq!(indices(&p, 3, 2).0, Selection::Dense);
    }

    #[test]
    fn middle_tokens_are_invisible() {
        let p = StreamingLlmPolicy::paper_default();
        let (s, sel) = indices(&p, 9999, 1);
        assert_eq!(s, Selection::Sparse);
        let h = sel.head(0);
        assert!(!h.contains(&5000));
        assert!(h.contains(&(10000 - 1)));
        assert!(h.contains(&0));
    }
}
