//! Serve-time Kascade policy: anchor layers extract Top-k, reuse layers
//! consume the indices after head remapping (Secs. 3.2-3.5).

use super::{Selection, SparsePolicy};
use crate::attention::{self, CostTracker, KvCache};
use crate::kascade::{KascadePlan, LayerRole};

/// Head-aware Kascade (the paper's default).
pub struct KascadePolicy {
    pub plan: KascadePlan,
    /// Last Top-k index sets per anchor layer (decode path).
    decode_idx: Vec<Option<Vec<Vec<u32>>>>,
    /// Per anchor layer, per **absolute** Q-tile index sets (prefill
    /// path).  Tiles are keyed by `start / PREFILL_TILE` so state stays
    /// consistent across chunked-prefill calls; an anchor that falls back
    /// to dense clears its slot (empty = no indices for this tile).
    prefill_idx: Vec<Vec<Vec<Vec<u32>>>>,
}

impl KascadePolicy {
    pub fn new(plan: KascadePlan) -> Self {
        let n = plan.n_layers;
        Self { plan, decode_idx: vec![None; n], prefill_idx: vec![Vec::new(); n] }
    }

    fn remap(&self, layer: usize, anchor_idx: &[Vec<u32>]) -> Vec<Vec<u32>> {
        self.plan.head_map[layer]
            .iter()
            .map(|&ha| anchor_idx[ha].clone())
            .collect()
    }
}

impl SparsePolicy for KascadePolicy {
    fn name(&self) -> String {
        "kascade".into()
    }

    fn reset(&mut self) {
        self.decode_idx.iter_mut().for_each(|s| *s = None);
        self.prefill_idx.iter_mut().for_each(|s| s.clear());
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Selection {
        let k = self.plan.topk.k(cache.len);
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                // dense output; still extract fresh indices for the segment
                if k < cache.len {
                    let pooled = attention::decode_pooled_scores(q, cache, g, cost);
                    self.decode_idx[layer] = Some(attention::select_topk(&pooled, k, cost));
                } else {
                    self.decode_idx[layer] = None;
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= cache.len {
                    self.decode_idx[layer] = None;
                    return Selection::Dense;
                }
                let pooled = attention::decode_pooled_scores(q, cache, g, cost);
                let idx = attention::select_topk(&pooled, k, cost);
                self.decode_idx[layer] = Some(idx.clone());
                Selection::Sparse(idx)
            }
            LayerRole::Reuse { anchor } => match &self.decode_idx[anchor] {
                Some(idx) => Selection::Sparse(self.remap(layer, idx)),
                None => Selection::Dense, // anchor ran dense (short context)
            },
        }
    }

    fn prefill_tile(
        &mut self,
        layer: usize,
        tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Selection {
        let n_q = cache.n_kv * g;
        let tile_len = qs.len() / (n_q * cache.d);
        let kv_len = start + tile_len;
        let k = self.plan.topk.k(kv_len);
        // always write the slot: a dense fallback (None) must CLEAR any
        // previously stored tile so a reuse layer can never go sparse with
        // indices its anchor did not produce for this query range
        let store = |slot: &mut Vec<Vec<Vec<u32>>>, tile: usize, idx: Option<Vec<Vec<u32>>>| {
            while slot.len() <= tile {
                slot.push(Vec::new());
            }
            slot[tile] = idx.unwrap_or_default();
        };
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                if k < kv_len {
                    let pooled = attention::prefill_pooled_scores(qs, start, cache, g, cost);
                    let idx = attention::select_topk(&pooled, k, cost);
                    store(&mut self.prefill_idx[layer], tile, Some(idx));
                } else {
                    store(&mut self.prefill_idx[layer], tile, None);
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= kv_len {
                    store(&mut self.prefill_idx[layer], tile, None);
                    return Selection::Dense;
                }
                let pooled = attention::prefill_pooled_scores(qs, start, cache, g, cost);
                let idx = attention::select_topk(&pooled, k, cost);
                store(&mut self.prefill_idx[layer], tile, Some(idx.clone()));
                Selection::Sparse(idx)
            }
            LayerRole::Reuse { anchor } => {
                let slot = &self.prefill_idx[anchor];
                if tile < slot.len() && !slot[tile].is_empty() {
                    let idx = self.remap(layer, &slot[tile]);
                    Selection::Sparse(idx)
                } else {
                    Selection::Dense
                }
            }
        }
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(KascadePolicy::new(self.plan.clone())))
    }
}

/// Ablation variant (Sec. 3.5 / Tables 1-2 "All Heads Pooled"): one shared
/// Top-k set per anchor layer, pooled across *all* heads; no remapping.
pub struct KascadeAllPooledPolicy {
    pub plan: KascadePlan,
    decode_idx: Vec<Option<Vec<u32>>>,
    prefill_idx: Vec<Vec<Vec<u32>>>,
}

impl KascadeAllPooledPolicy {
    pub fn new(plan: KascadePlan) -> Self {
        let n = plan.n_layers;
        Self { plan, decode_idx: vec![None; n], prefill_idx: vec![Vec::new(); n] }
    }

    fn pool_all(pooled: &[Vec<f32>]) -> Vec<f32> {
        let len = pooled[0].len();
        let inv = 1.0 / pooled.len() as f32;
        let mut out = vec![0.0f32; len];
        for head in pooled {
            for (o, &x) in out.iter_mut().zip(head.iter()) {
                *o += x * inv;
            }
        }
        out
    }

    fn broadcast(&self, idx: &[u32]) -> Vec<Vec<u32>> {
        vec![idx.to_vec(); self.plan.n_kv_heads]
    }
}

impl SparsePolicy for KascadeAllPooledPolicy {
    fn name(&self) -> String {
        "kascade-all-pooled".into()
    }

    fn reset(&mut self) {
        self.decode_idx.iter_mut().for_each(|s| *s = None);
        self.prefill_idx.iter_mut().for_each(|s| s.clear());
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Selection {
        let k = self.plan.topk.k(cache.len);
        let extract = |cost: &mut CostTracker| {
            let pooled = attention::decode_pooled_scores(q, cache, g, cost);
            let all = Self::pool_all(&pooled);
            cost.topk_items += all.len() as u64;
            crate::tensor::topk_indices(&all, k)
        };
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                self.decode_idx[layer] = (k < cache.len).then(|| extract(cost));
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= cache.len {
                    self.decode_idx[layer] = None;
                    return Selection::Dense;
                }
                let idx = extract(cost);
                self.decode_idx[layer] = Some(idx.clone());
                Selection::Sparse(self.broadcast(&idx))
            }
            LayerRole::Reuse { anchor } => match &self.decode_idx[anchor] {
                Some(idx) => Selection::Sparse(self.broadcast(idx)),
                None => Selection::Dense,
            },
        }
    }

    fn prefill_tile(
        &mut self,
        layer: usize,
        tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Selection {
        let n_q = cache.n_kv * g;
        let tile_len = qs.len() / (n_q * cache.d);
        let kv_len = start + tile_len;
        let k = self.plan.topk.k(kv_len);
        let extract = |cost: &mut CostTracker| {
            let pooled = attention::prefill_pooled_scores(qs, start, cache, g, cost);
            let all = Self::pool_all(&pooled);
            cost.topk_items += all.len() as u64;
            crate::tensor::topk_indices(&all, k)
        };
        // as in [`KascadePolicy`]: dense fallbacks clear the slot, keyed
        // by absolute tile, so stale indices never leak across chunks
        let store = |slot: &mut Vec<Vec<u32>>, tile: usize, idx: Vec<u32>| {
            while slot.len() <= tile {
                slot.push(Vec::new());
            }
            slot[tile] = idx;
        };
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                if k < kv_len {
                    let idx = extract(cost);
                    store(&mut self.prefill_idx[layer], tile, idx);
                } else {
                    store(&mut self.prefill_idx[layer], tile, Vec::new());
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= kv_len {
                    store(&mut self.prefill_idx[layer], tile, Vec::new());
                    return Selection::Dense;
                }
                let idx = extract(cost);
                store(&mut self.prefill_idx[layer], tile, idx.clone());
                Selection::Sparse(self.broadcast(&idx))
            }
            LayerRole::Reuse { anchor } => {
                let slot = &self.prefill_idx[anchor];
                if tile < slot.len() && !slot[tile].is_empty() {
                    Selection::Sparse(self.broadcast(&slot[tile]))
                } else {
                    Selection::Dense
                }
            }
        }
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(KascadeAllPooledPolicy::new(self.plan.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopKRule;
    use crate::tensor::Rng;

    fn setup() -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(3);
        let (n_kv, g, d, len) = (2, 2, 16, 512);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(n_kv, d, len);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        (q, c)
    }

    fn plan() -> KascadePlan {
        let mut p = KascadePlan::from_anchors(8, 2, vec![0, 2, 5], TopKRule::new(0.1, 16));
        // layer 3 reads anchor 2 with swapped heads
        p.head_map[3] = vec![1, 0];
        p
    }

    #[test]
    fn anchor_then_reuse_shares_indices_with_remap() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        // layer 0: dense + extraction
        assert_eq!(pol.decode(0, &q, &c, 2, &mut cost), Selection::Dense);
        // layer 1 reuses anchor 0
        let s1 = pol.decode(1, &q, &c, 2, &mut cost);
        let idx0 = pol.decode_idx[0].clone().unwrap();
        assert_eq!(s1, Selection::Sparse(idx0.clone()));
        // layer 2 is an anchor: fresh indices
        let s2 = pol.decode(2, &q, &c, 2, &mut cost);
        let idx2 = pol.decode_idx[2].clone().unwrap();
        assert_eq!(s2, Selection::Sparse(idx2.clone()));
        // layer 3 reuses anchor 2 with swapped head map
        match pol.decode(3, &q, &c, 2, &mut cost) {
            Selection::Sparse(idx) => {
                assert_eq!(idx[0], idx2[1]);
                assert_eq!(idx[1], idx2[0]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn reuse_layers_pay_no_score_cost() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        pol.decode(2, &q, &c, 2, &mut cost);
        let after_anchor = cost.score_key_reads;
        pol.decode(3, &q, &c, 2, &mut cost);
        pol.decode(4, &q, &c, 2, &mut cost);
        assert_eq!(cost.score_key_reads, after_anchor);
    }

    #[test]
    fn short_context_falls_back_to_dense() {
        let mut r = Rng::new(4);
        let mut q = vec![0.0; 2 * 2 * 16];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(2, 16, 64);
        let k = vec![0.0; 32];
        for _ in 0..8 {
            c.push(&k, &k);
        }
        let mut pol = KascadePolicy::new(KascadePlan::from_anchors(
            8,
            2,
            vec![0, 2],
            TopKRule::default(), // min_k 128 > 8
        ));
        let mut cost = CostTracker::default();
        assert_eq!(pol.decode(2, &q, &c, 2, &mut cost), Selection::Dense);
        assert_eq!(pol.decode(3, &q, &c, 2, &mut cost), Selection::Dense);
    }

    #[test]
    fn all_pooled_shares_one_set_across_heads() {
        let (q, c) = setup();
        let mut pol = KascadeAllPooledPolicy::new(plan());
        let mut cost = CostTracker::default();
        pol.decode(0, &q, &c, 2, &mut cost);
        match pol.decode(2, &q, &c, 2, &mut cost) {
            Selection::Sparse(idx) => assert_eq!(idx[0], idx[1]),
            _ => panic!(),
        }
    }

    #[test]
    fn reset_clears_state() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        pol.decode(0, &q, &c, 2, &mut cost);
        assert!(pol.decode_idx[0].is_some());
        pol.reset();
        assert!(pol.decode_idx.iter().all(|s| s.is_none()));
    }

    #[test]
    fn prefill_anchor_then_reuse_per_tile() {
        let mut r = Rng::new(5);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        let mut c = KvCache::new(n_kv, d, 512);
        for _ in 0..256 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        let tile_len = 128;
        let mut qs = vec![0.0; tile_len * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        // anchor layer 2, tile 1 (positions 128..256)
        let s = pol.prefill_tile(2, 1, 128, &qs, &c, g, &mut cost);
        let idx = match s {
            Selection::Sparse(i) => i,
            _ => panic!("anchor tile should be sparse at 256 ctx / k=25"),
        };
        // reuse layer 4, same tile: identical sets (identity map on 4)
        match pol.prefill_tile(4, 1, 128, &qs, &c, g, &mut cost) {
            Selection::Sparse(i) => assert_eq!(i, idx),
            _ => panic!(),
        }
        // tile that the anchor never saw -> dense fallback
        assert_eq!(pol.prefill_tile(4, 3, 384, &qs, &c, g, &mut cost), Selection::Dense);
    }

    /// A dense fallback must CLEAR previously stored indices for the same
    /// absolute tile — the old `store(..., None)` left them in place, so a
    /// reuse layer went sparse with indices its anchor never produced for
    /// that query range.
    #[test]
    fn prefill_dense_fallback_clears_stale_tile_state() {
        let mut r = Rng::new(6);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        // big context: anchor goes sparse at tile 0 and stores indices
        let mut big = KvCache::new(n_kv, d, 512);
        for _ in 0..512 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            big.push(&k, &v);
        }
        let mut qs_big = vec![0.0; 128 * n_q * d];
        r.fill_normal(&mut qs_big, 1.0);
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        match pol.prefill_tile(2, 0, 0, &qs_big, &big, g, &mut cost) {
            Selection::Sparse(_) => {}
            _ => panic!("anchor must be sparse at 128 ctx / k=16"),
        }
        // tiny context view over the same tile: k >= kv_len -> dense,
        // which must clear the stored slot
        let mut small = KvCache::new(n_kv, d, 16);
        let kz = vec![0.0; n_kv * d];
        for _ in 0..8 {
            small.push(&kz, &kz);
        }
        let mut qs_small = vec![0.0; 8 * n_q * d];
        r.fill_normal(&mut qs_small, 1.0);
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_small, &small, g, &mut cost),
            Selection::Dense
        );
        // the reuse layer must NOT consume the stale tile-0 indices
        assert_eq!(
            pol.prefill_tile(4, 0, 0, &qs_small, &small, g, &mut cost),
            Selection::Dense
        );
    }

    /// Anchor Top-k extracted from an int8 cache must select the same
    /// tiles as from f32 when the score landscape has margin: pooled
    /// scoring runs fused over the quantized keys (no dequant cost) and
    /// the per-tile quantization error is far below the planted gap.
    #[test]
    fn int8_cache_matches_f32_topk_selection() {
        use crate::config::KvDtype;
        let mut r = Rng::new(88);
        let (n_kv, g, d, len) = (2usize, 2usize, 16usize, 256usize);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cf = KvCache::new(n_kv, d, len);
        let mut cq = KvCache::with_opts(n_kv, d, len, 16, KvDtype::Int8);
        // exactly k = 25 strongly aligned keys; the rest low noise
        let strong: Vec<usize> = (0..25).map(|i| i * 10 + 3).collect();
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.05);
            r.fill_normal(&mut v, 1.0);
            if strong.contains(&p) {
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        let mk = || {
            let p = KascadePlan::from_anchors(8, 2, vec![0, 2], TopKRule::new(0.1, 16));
            KascadePolicy::new(p)
        };
        let (mut pf, mut pq) = (mk(), mk());
        let mut cost_f = CostTracker::default();
        let mut cost_q = CostTracker::default();
        let sf = pf.decode(2, &q, &cf, g, &mut cost_f);
        let sq = pq.decode(2, &q, &cq, g, &mut cost_q);
        assert_eq!(cost_q.dequant_rows, 0, "anchor scoring is fused — no dequant");
        match (sf, sq) {
            (Selection::Sparse(a), Selection::Sparse(b)) => {
                for (ha, hb) in a.iter().zip(&b) {
                    let mut sa = ha.clone();
                    let mut sb = hb.clone();
                    sa.sort_unstable();
                    sb.sort_unstable();
                    assert_eq!(sa, sb, "int8 Top-k selection diverged from f32");
                    for &s in &strong {
                        assert!(sa.contains(&(s as u32)), "planted key {s} missing");
                    }
                }
            }
            _ => panic!("expected sparse selections"),
        }
    }

    #[test]
    fn all_pooled_dense_fallback_clears_stale_tile_state() {
        let mut r = Rng::new(7);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        let mut big = KvCache::new(n_kv, d, 512);
        for _ in 0..512 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            big.push(&k, &v);
        }
        let mut qs_big = vec![0.0; 128 * n_q * d];
        r.fill_normal(&mut qs_big, 1.0);
        let mut pol = KascadeAllPooledPolicy::new(plan());
        let mut cost = CostTracker::default();
        match pol.prefill_tile(2, 0, 0, &qs_big, &big, g, &mut cost) {
            Selection::Sparse(_) => {}
            _ => panic!("anchor must be sparse"),
        }
        let mut small = KvCache::new(n_kv, d, 16);
        let kz = vec![0.0; n_kv * d];
        for _ in 0..8 {
            small.push(&kz, &kz);
        }
        let mut qs_small = vec![0.0; 8 * n_q * d];
        r.fill_normal(&mut qs_small, 1.0);
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_small, &small, g, &mut cost),
            Selection::Dense
        );
        assert_eq!(
            pol.prefill_tile(3, 0, 0, &qs_small, &small, g, &mut cost),
            Selection::Dense
        );
    }
}
