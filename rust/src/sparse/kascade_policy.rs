//! Serve-time Kascade policy: anchor layers extract Top-k, reuse layers
//! consume the indices after head remapping (Secs. 3.2-3.5).
//!
//! All index state lives in flat [`IndexSet`]s whose buffers are reused
//! across steps: anchor refreshes copy the scratch selection into the
//! per-layer slot in place, reuse layers remap straight into the scratch
//! — the steady-state decode path allocates nothing.

use super::{Selection, SparsePolicy};
use crate::attention::{self, AttnScratch, CostTracker, IndexSet, KvCache};
use crate::kascade::{KascadePlan, LayerRole};

/// Head-aware Kascade (the paper's default).
pub struct KascadePolicy {
    pub plan: KascadePlan,
    /// Last Top-k index sets per anchor layer (decode path); valid only
    /// where `decode_has` is set (buffers are retained across dense
    /// fallbacks so re-going sparse never reallocates).
    decode_idx: Vec<IndexSet>,
    decode_has: Vec<bool>,
    /// Per anchor layer, per **absolute** Q-tile index sets (prefill
    /// path).  Tiles are keyed by `start / PREFILL_TILE` so state stays
    /// consistent across chunked-prefill calls; an anchor that falls back
    /// to dense clears its slot (empty = no indices for this tile).
    prefill_idx: Vec<Vec<IndexSet>>,
}

impl KascadePolicy {
    pub fn new(plan: KascadePlan) -> Self {
        let n = plan.n_layers;
        Self {
            plan,
            decode_idx: (0..n).map(|_| IndexSet::new()).collect(),
            decode_has: vec![false; n],
            prefill_idx: (0..n).map(|_| Vec::new()).collect(),
        }
    }

    /// Head-remap `src`'s per-head sets into `sel` (layer's head h reads
    /// the anchor's head `head_map[layer][h]`).
    fn remap_into(head_map: &[usize], src: &IndexSet, sel: &mut IndexSet) {
        sel.clear();
        for &ha in head_map {
            sel.extend_head(src.head(ha));
        }
    }

    /// Grow `slot` to cover `tile` and return its entry.
    fn slot_mut(slot: &mut Vec<IndexSet>, tile: usize) -> &mut IndexSet {
        while slot.len() <= tile {
            slot.push(IndexSet::new());
        }
        &mut slot[tile]
    }

    #[cfg(test)]
    pub(crate) fn decode_set(&self, layer: usize) -> Option<&IndexSet> {
        if self.decode_has[layer] {
            Some(&self.decode_idx[layer])
        } else {
            None
        }
    }
}

impl SparsePolicy for KascadePolicy {
    fn name(&self) -> String {
        "kascade".into()
    }

    fn reset(&mut self) {
        self.decode_has.iter_mut().for_each(|s| *s = false);
        self.prefill_idx.iter_mut().for_each(|s| s.clear());
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        let k = self.plan.topk.k(cache.len);
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                // dense output; still extract fresh indices for the segment
                if k < cache.len {
                    attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
                    attention::select_topk(scratch, k, cost);
                    self.decode_idx[layer].copy_from(&scratch.sel);
                    self.decode_has[layer] = true;
                } else {
                    self.decode_has[layer] = false;
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= cache.len {
                    self.decode_has[layer] = false;
                    return Selection::Dense;
                }
                attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
                attention::select_topk(scratch, k, cost);
                self.decode_idx[layer].copy_from(&scratch.sel);
                self.decode_has[layer] = true;
                Selection::Sparse
            }
            LayerRole::Reuse { anchor } => {
                if self.decode_has[anchor] {
                    Self::remap_into(
                        &self.plan.head_map[layer],
                        &self.decode_idx[anchor],
                        &mut scratch.sel,
                    );
                    Selection::Sparse
                } else {
                    Selection::Dense // anchor ran dense (short context)
                }
            }
        }
    }

    fn prefill_tile(
        &mut self,
        layer: usize,
        tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        let n_q = cache.n_kv * g;
        let tile_len = qs.len() / (n_q * cache.d);
        let kv_len = start + tile_len;
        let k = self.plan.topk.k(kv_len);
        // always write the slot: a dense fallback must CLEAR any
        // previously stored tile so a reuse layer can never go sparse with
        // indices its anchor did not produce for this query range
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                if k < kv_len {
                    let planes = &mut scratch.planes;
                    attention::prefill_pooled_scores(qs, start, cache, g, planes, cost);
                    attention::select_topk(scratch, k, cost);
                    Self::slot_mut(&mut self.prefill_idx[layer], tile).copy_from(&scratch.sel);
                } else {
                    Self::slot_mut(&mut self.prefill_idx[layer], tile).clear();
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= kv_len {
                    Self::slot_mut(&mut self.prefill_idx[layer], tile).clear();
                    return Selection::Dense;
                }
                let planes = &mut scratch.planes;
                attention::prefill_pooled_scores(qs, start, cache, g, planes, cost);
                attention::select_topk(scratch, k, cost);
                Self::slot_mut(&mut self.prefill_idx[layer], tile).copy_from(&scratch.sel);
                Selection::Sparse
            }
            LayerRole::Reuse { anchor } => {
                let slot = &self.prefill_idx[anchor];
                if tile < slot.len() && !slot[tile].is_empty() {
                    Self::remap_into(&self.plan.head_map[layer], &slot[tile], &mut scratch.sel);
                    Selection::Sparse
                } else {
                    Selection::Dense
                }
            }
        }
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    /// Anchor layers (and anchor-0) score every stored position when they
    /// re-extract Top-k, so only reuse layers can run under a bounded hot
    /// set — their index sets are remapped from cached anchor selections
    /// and never scan the full context.
    fn scans_all_positions(&self, layer: usize) -> bool {
        !matches!(self.plan.role(layer), LayerRole::Reuse { .. })
    }

    /// The union of every cached anchor-layer Top-k selection, as tile
    /// ids.  Head remapping permutes *which* head reads *which* set, not
    /// the positions inside them, so this union is exactly the position
    /// set any reuse layer can touch until the anchors re-select.
    fn needed_tiles(&self, page_size: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let ps = page_size as u32;
        let mut any = false;
        for (layer, &has) in self.decode_has.iter().enumerate() {
            if !has {
                continue;
            }
            any = true;
            let idx = &self.decode_idx[layer];
            for h in 0..idx.n_heads() {
                for &p in idx.head(h) {
                    out.push(p / ps);
                }
            }
        }
        if !any {
            return false;
        }
        out.sort_unstable();
        out.dedup();
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(KascadePolicy::new(self.plan.clone())))
    }
}

/// Ablation variant (Sec. 3.5 / Tables 1-2 "All Heads Pooled"): one shared
/// Top-k set per anchor layer, pooled across *all* heads; no remapping.
pub struct KascadeAllPooledPolicy {
    pub plan: KascadePlan,
    decode_idx: Vec<Option<Vec<u32>>>,
    prefill_idx: Vec<Vec<Vec<u32>>>,
    /// reused all-heads pooled distribution
    all: Vec<f32>,
}

impl KascadeAllPooledPolicy {
    pub fn new(plan: KascadePlan) -> Self {
        let n = plan.n_layers;
        Self { plan, decode_idx: vec![None; n], prefill_idx: vec![Vec::new(); n], all: Vec::new() }
    }
}

impl SparsePolicy for KascadeAllPooledPolicy {
    fn name(&self) -> String {
        "kascade-all-pooled".into()
    }

    fn reset(&mut self) {
        self.decode_idx.iter_mut().for_each(|s| *s = None);
        self.prefill_idx.iter_mut().for_each(|s| s.clear());
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        let k = self.plan.topk.k(cache.len);
        let n_kv = cache.n_kv;
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                self.decode_idx[layer] = if k < cache.len {
                    attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
                    super::pool_all_into(&scratch.planes, &mut self.all);
                    cost.topk_items += self.all.len() as u64;
                    Some(crate::tensor::topk_indices(&self.all, k))
                } else {
                    None
                };
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= cache.len {
                    self.decode_idx[layer] = None;
                    return Selection::Dense;
                }
                attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
                super::pool_all_into(&scratch.planes, &mut self.all);
                cost.topk_items += self.all.len() as u64;
                let idx = crate::tensor::topk_indices(&self.all, k);
                super::broadcast_into(&idx, n_kv, &mut scratch.sel);
                self.decode_idx[layer] = Some(idx);
                Selection::Sparse
            }
            LayerRole::Reuse { anchor } => match &self.decode_idx[anchor] {
                Some(idx) => {
                    super::broadcast_into(idx, n_kv, &mut scratch.sel);
                    Selection::Sparse
                }
                None => Selection::Dense,
            },
        }
    }

    fn prefill_tile(
        &mut self,
        layer: usize,
        tile: usize,
        start: usize,
        qs: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        let n_q = cache.n_kv * g;
        let n_kv = cache.n_kv;
        let tile_len = qs.len() / (n_q * cache.d);
        let kv_len = start + tile_len;
        let k = self.plan.topk.k(kv_len);
        // as in [`KascadePolicy`]: dense fallbacks clear the slot, keyed
        // by absolute tile, so stale indices never leak across chunks
        let store = |slot: &mut Vec<Vec<u32>>, tile: usize, idx: Vec<u32>| {
            while slot.len() <= tile {
                slot.push(Vec::new());
            }
            slot[tile] = idx;
        };
        match self.plan.role(layer) {
            LayerRole::Anchor0 => {
                if k < kv_len {
                    attention::prefill_pooled_scores(qs, start, cache, g, &mut scratch.planes, cost);
                    super::pool_all_into(&scratch.planes, &mut self.all);
                    cost.topk_items += self.all.len() as u64;
                    let idx = crate::tensor::topk_indices(&self.all, k);
                    store(&mut self.prefill_idx[layer], tile, idx);
                } else {
                    store(&mut self.prefill_idx[layer], tile, Vec::new());
                }
                Selection::Dense
            }
            LayerRole::Anchor => {
                if k >= kv_len {
                    store(&mut self.prefill_idx[layer], tile, Vec::new());
                    return Selection::Dense;
                }
                attention::prefill_pooled_scores(qs, start, cache, g, &mut scratch.planes, cost);
                super::pool_all_into(&scratch.planes, &mut self.all);
                cost.topk_items += self.all.len() as u64;
                let idx = crate::tensor::topk_indices(&self.all, k);
                super::broadcast_into(&idx, n_kv, &mut scratch.sel);
                store(&mut self.prefill_idx[layer], tile, idx);
                Selection::Sparse
            }
            LayerRole::Reuse { anchor } => {
                let slot = &self.prefill_idx[anchor];
                if tile < slot.len() && !slot[tile].is_empty() {
                    super::broadcast_into(&slot[tile], n_kv, &mut scratch.sel);
                    Selection::Sparse
                } else {
                    Selection::Dense
                }
            }
        }
    }

    fn sparse_prefill(&self) -> bool {
        true
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(KascadeAllPooledPolicy::new(self.plan.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopKRule;
    use crate::tensor::Rng;

    fn setup() -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(3);
        let (n_kv, g, d, len) = (2, 2, 16, 512);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(n_kv, d, len);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        (q, c)
    }

    fn plan() -> KascadePlan {
        let mut p = KascadePlan::from_anchors(8, 2, vec![0, 2, 5], TopKRule::new(0.1, 16));
        // layer 3 reads anchor 2 with swapped heads
        p.head_map[3] = vec![1, 0];
        p
    }

    #[test]
    fn anchor_then_reuse_shares_indices_with_remap() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        // layer 0: dense + extraction
        assert_eq!(pol.decode(0, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        let idx0 = pol.decode_set(0).unwrap().clone();
        // layer 1 reuses anchor 0 (identity map)
        assert_eq!(pol.decode(1, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel, idx0);
        // layer 2 is an anchor: fresh indices
        assert_eq!(pol.decode(2, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        let idx2 = pol.decode_set(2).unwrap().clone();
        assert_eq!(scratch.sel, idx2);
        // layer 3 reuses anchor 2 with swapped head map
        assert_eq!(pol.decode(3, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel.head(0), idx2.head(1));
        assert_eq!(scratch.sel.head(1), idx2.head(0));
    }

    #[test]
    fn reuse_layers_pay_no_score_cost() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        pol.decode(2, &q, &c, 2, &mut scratch, &mut cost);
        let after_anchor = cost.score_key_reads;
        pol.decode(3, &q, &c, 2, &mut scratch, &mut cost);
        pol.decode(4, &q, &c, 2, &mut scratch, &mut cost);
        assert_eq!(cost.score_key_reads, after_anchor);
    }

    #[test]
    fn short_context_falls_back_to_dense() {
        let mut r = Rng::new(4);
        let mut q = vec![0.0; 2 * 2 * 16];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(2, 16, 64);
        let k = vec![0.0; 32];
        for _ in 0..8 {
            c.push(&k, &k);
        }
        let mut pol = KascadePolicy::new(KascadePlan::from_anchors(
            8,
            2,
            vec![0, 2],
            TopKRule::default(), // min_k 128 > 8
        ));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(pol.decode(2, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        assert_eq!(pol.decode(3, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
    }

    #[test]
    fn all_pooled_shares_one_set_across_heads() {
        let (q, c) = setup();
        let mut pol = KascadeAllPooledPolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        assert_eq!(pol.decode(2, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel.head(0), scratch.sel.head(1));
    }

    #[test]
    fn reset_clears_state() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        assert!(pol.decode_set(0).is_some());
        pol.reset();
        assert!((0..8).all(|l| pol.decode_set(l).is_none()));
    }

    #[test]
    fn prefill_anchor_then_reuse_per_tile() {
        let mut r = Rng::new(5);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        let mut c = KvCache::new(n_kv, d, 512);
        for _ in 0..256 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        let tile_len = 128;
        let mut qs = vec![0.0; tile_len * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        // anchor layer 2, tile 1 (positions 128..256)
        assert_eq!(
            pol.prefill_tile(2, 1, 128, &qs, &c, g, &mut scratch, &mut cost),
            Selection::Sparse,
            "anchor tile should be sparse at 256 ctx / k=25"
        );
        let idx = scratch.sel.clone();
        // reuse layer 4, same tile: identical sets (identity map on 4)
        assert_eq!(
            pol.prefill_tile(4, 1, 128, &qs, &c, g, &mut scratch, &mut cost),
            Selection::Sparse
        );
        assert_eq!(scratch.sel, idx);
        // tile that the anchor never saw -> dense fallback
        assert_eq!(
            pol.prefill_tile(4, 3, 384, &qs, &c, g, &mut scratch, &mut cost),
            Selection::Dense
        );
    }

    /// A dense fallback must CLEAR previously stored indices for the same
    /// absolute tile — otherwise a reuse layer goes sparse with indices
    /// its anchor never produced for that query range.
    #[test]
    fn prefill_dense_fallback_clears_stale_tile_state() {
        let mut r = Rng::new(6);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        // big context: anchor goes sparse at tile 0 and stores indices
        let mut big = KvCache::new(n_kv, d, 512);
        for _ in 0..512 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            big.push(&k, &v);
        }
        let mut qs_big = vec![0.0; 128 * n_q * d];
        r.fill_normal(&mut qs_big, 1.0);
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_big, &big, g, &mut scratch, &mut cost),
            Selection::Sparse,
            "anchor must be sparse at 128 ctx / k=16"
        );
        // tiny context view over the same tile: k >= kv_len -> dense,
        // which must clear the stored slot
        let mut small = KvCache::new(n_kv, d, 16);
        let kz = vec![0.0; n_kv * d];
        for _ in 0..8 {
            small.push(&kz, &kz);
        }
        let mut qs_small = vec![0.0; 8 * n_q * d];
        r.fill_normal(&mut qs_small, 1.0);
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_small, &small, g, &mut scratch, &mut cost),
            Selection::Dense
        );
        // the reuse layer must NOT consume the stale tile-0 indices
        assert_eq!(
            pol.prefill_tile(4, 0, 0, &qs_small, &small, g, &mut scratch, &mut cost),
            Selection::Dense
        );
    }

    /// Anchor Top-k extracted from an int8 cache must select the same
    /// tiles as from f32 when the score landscape has margin: pooled
    /// scoring runs fused over the quantized keys (no dequant cost) and
    /// the per-tile quantization error is far below the planted gap.
    #[test]
    fn int8_cache_matches_f32_topk_selection() {
        use crate::config::KvDtype;
        let mut r = Rng::new(88);
        let (n_kv, g, d, len) = (2usize, 2usize, 16usize, 256usize);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cf = KvCache::new(n_kv, d, len);
        let mut cq = KvCache::with_opts(n_kv, d, len, 16, KvDtype::Int8);
        // exactly k = 25 strongly aligned keys; the rest low noise
        let strong: Vec<usize> = (0..25).map(|i| i * 10 + 3).collect();
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.05);
            r.fill_normal(&mut v, 1.0);
            if strong.contains(&p) {
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        let mk = || {
            let p = KascadePlan::from_anchors(8, 2, vec![0, 2], TopKRule::new(0.1, 16));
            KascadePolicy::new(p)
        };
        let (mut pf, mut pq) = (mk(), mk());
        let mut cost_f = CostTracker::default();
        let mut cost_q = CostTracker::default();
        let mut scr_f = AttnScratch::new();
        let mut scr_q = AttnScratch::new();
        let sf = pf.decode(2, &q, &cf, g, &mut scr_f, &mut cost_f);
        let sq = pq.decode(2, &q, &cq, g, &mut scr_q, &mut cost_q);
        assert_eq!(cost_q.dequant_rows, 0, "anchor scoring is fused — no dequant");
        assert_eq!((sf, sq), (Selection::Sparse, Selection::Sparse));
        for h in 0..n_kv {
            let mut sa = scr_f.sel.head(h).to_vec();
            let mut sb = scr_q.sel.head(h).to_vec();
            sa.sort_unstable();
            sb.sort_unstable();
            assert_eq!(sa, sb, "int8 Top-k selection diverged from f32");
            for &s in &strong {
                assert!(sa.contains(&(s as u32)), "planted key {s} missing");
            }
        }
    }

    /// The tier hint (`needed_tiles`) must be the sorted, deduplicated
    /// union of every cached anchor selection — and report "no hint"
    /// before any anchor has extracted indices.
    #[test]
    fn needed_tiles_unions_anchor_selections() {
        let (q, c) = setup();
        let mut pol = KascadePolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        let mut tiles = Vec::new();
        assert!(!pol.needed_tiles(16, &mut tiles), "no anchors cached yet");
        pol.decode(0, &q, &c, 2, &mut scratch, &mut cost);
        pol.decode(2, &q, &c, 2, &mut scratch, &mut cost);
        pol.decode(5, &q, &c, 2, &mut scratch, &mut cost);
        assert!(pol.needed_tiles(16, &mut tiles));
        assert!(!tiles.is_empty());
        assert!(tiles.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        // every cached anchor position maps into the hint
        for l in [0usize, 2, 5] {
            let idx = pol.decode_set(l).unwrap();
            for h in 0..idx.n_heads() {
                for &p in idx.head(h) {
                    assert!(tiles.binary_search(&(p / 16)).is_ok());
                }
            }
        }
        // role split: anchors scan all positions, reuse layers don't
        assert!(pol.scans_all_positions(0));
        assert!(pol.scans_all_positions(2));
        assert!(!pol.scans_all_positions(3));
        assert!(!pol.scans_all_positions(4));
    }

    #[test]
    fn all_pooled_dense_fallback_clears_stale_tile_state() {
        let mut r = Rng::new(7);
        let (n_kv, g, d) = (2, 2, 16);
        let n_q = n_kv * g;
        let mut big = KvCache::new(n_kv, d, 512);
        for _ in 0..512 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            big.push(&k, &v);
        }
        let mut qs_big = vec![0.0; 128 * n_q * d];
        r.fill_normal(&mut qs_big, 1.0);
        let mut pol = KascadeAllPooledPolicy::new(plan());
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_big, &big, g, &mut scratch, &mut cost),
            Selection::Sparse,
            "anchor must be sparse"
        );
        let mut small = KvCache::new(n_kv, d, 16);
        let kz = vec![0.0; n_kv * d];
        for _ in 0..8 {
            small.push(&kz, &kz);
        }
        let mut qs_small = vec![0.0; 8 * n_q * d];
        r.fill_normal(&mut qs_small, 1.0);
        assert_eq!(
            pol.prefill_tile(2, 0, 0, &qs_small, &small, g, &mut scratch, &mut cost),
            Selection::Dense
        );
        assert_eq!(
            pol.prefill_tile(3, 0, 0, &qs_small, &small, g, &mut scratch, &mut cost),
            Selection::Dense
        );
    }
}
