//! LessIsMore / TidalDecode-like baseline (Yang et al., 2024/2025):
//! Top-k indices recomputed every decode step at a few *manually chosen*
//! layers, shared across all heads, reused by the layers in between.
//! Decode-only (full prefill), no head remapping — the two properties the
//! paper's head-aware design improves on.

use super::{Selection, SparsePolicy};
use crate::attention::{self, AttnScratch, CostTracker, KvCache};
use crate::config::TopKRule;

pub struct LessIsMorePolicy {
    pub recompute_layers: Vec<usize>,
    pub rule: TopKRule,
    selected: Vec<Option<Vec<u32>>>,
    /// reused all-heads pooled distribution
    all: Vec<f32>,
    n_layers: usize,
}

impl LessIsMorePolicy {
    pub fn new(n_layers: usize, recompute_layers: Vec<usize>, rule: TopKRule) -> Self {
        Self { recompute_layers, rule, selected: vec![None; n_layers], all: Vec::new(), n_layers }
    }

    fn source_of(&self, layer: usize) -> Option<usize> {
        self.recompute_layers.iter().rev().find(|&&f| f <= layer).copied()
    }
}

impl SparsePolicy for LessIsMorePolicy {
    fn name(&self) -> String {
        "lessismore".into()
    }

    fn reset(&mut self) {
        self.selected = vec![None; self.n_layers];
    }

    fn decode(
        &mut self,
        layer: usize,
        q: &[f32],
        cache: &KvCache,
        g: usize,
        scratch: &mut AttnScratch,
        cost: &mut CostTracker,
    ) -> Selection {
        let k = self.rule.k(cache.len);
        if k >= cache.len {
            return Selection::Dense;
        }
        if layer == 0 {
            return Selection::Dense; // first layer always dense
        }
        if self.recompute_layers.contains(&layer) {
            attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, cost);
            super::pool_all_into(&scratch.planes, &mut self.all);
            cost.topk_items += self.all.len() as u64;
            let idx = crate::tensor::topk_indices(&self.all, k);
            super::broadcast_into(&idx, cache.n_kv, &mut scratch.sel);
            self.selected[layer] = Some(idx);
            return Selection::Sparse;
        }
        match self.source_of(layer).and_then(|f| self.selected[f].as_ref()) {
            Some(idx) => {
                super::broadcast_into(idx, cache.n_kv, &mut scratch.sel);
                Selection::Sparse
            }
            None => Selection::Dense,
        }
    }

    fn fork_fresh(&self) -> Option<Box<dyn SparsePolicy>> {
        Some(Box::new(LessIsMorePolicy::new(
            self.n_layers,
            self.recompute_layers.clone(),
            self.rule,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn recompute_layers_refresh_every_step() {
        let mut r = Rng::new(10);
        let (n_kv, g, d, len) = (2, 2, 16, 512);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut c = KvCache::new(n_kv, d, len);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            c.push(&k, &v);
        }
        let mut pol = LessIsMorePolicy::new(8, vec![2, 5], TopKRule::new(0.1, 16));
        let mut cost = CostTracker::default();
        let mut scratch = AttnScratch::new();
        assert_eq!(pol.decode(0, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        // before first recompute
        assert_eq!(pol.decode(1, &q, &c, 2, &mut scratch, &mut cost), Selection::Dense);
        assert_eq!(pol.decode(2, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        let s2 = scratch.sel.clone();
        let reads_after_2 = cost.score_key_reads;
        assert_eq!(pol.decode(3, &q, &c, 2, &mut scratch, &mut cost), Selection::Sparse);
        assert_eq!(scratch.sel, s2);
        assert_eq!(cost.score_key_reads, reads_after_2, "reuse is free");
        // recompute layer always rescoring (unlike OmniKV)
        pol.decode(5, &q, &c, 2, &mut scratch, &mut cost);
        assert!(cost.score_key_reads > reads_after_2);
    }
}
