//! Prefix-affinity routing: per-replica chain-hash Bloom summaries and
//! the deterministic replica-selection rule (docs/gateway.md § affinity).
//!
//! The gateway hashes each incoming prompt into its block chain
//! ([`crate::coordinator::prefix_cache::chain_hashes`] — the same
//! hashes the in-replica prefix cache indexes by) and scores every
//! replica by how many LEADING blocks of that chain its summary already
//! holds.  Routing to the deepest-prefix replica converts a long shared
//! prefill into a snapshot resume on that replica; the summary is a
//! Bloom filter, so a false positive only costs a misrouted request
//! (one cache miss), never a wrong answer.

use crate::tensor::splitmix64;

/// Summary width in bits (2^16).  At two probes per hash this holds ~4k
/// distinct block hashes under ~1% false-positive rate — far beyond the
/// chain depth a single replica's prefix cache retains.
const SUMMARY_BITS: usize = 1 << 16;

/// Second-probe tweak so the two probe streams are independent.
const PROBE_SALT: u64 = 0xA5A5_5A5A_C3C3_3C3C;

/// A Bloom-filter summary of the block chain hashes a replica has been
/// routed (an over-approximation of what its prefix cache holds).
///
/// The summary ages generationally: inserts land in the *current* bit
/// plane, lookups consult the union of the current and *previous*
/// planes, and [`ChainSummary::decay`] retires the previous plane and
/// demotes the current one.  A hash that stops being observed survives
/// at most two decay windows, so a long-lived replica's filter can't
/// saturate into scoring every prompt as fully cached.
#[derive(Debug, Clone)]
pub struct ChainSummary {
    /// current generation — receives inserts
    bits: Vec<u64>,
    /// previous generation — read-only until the next decay retires it
    prev: Vec<u64>,
    inserted: u64,
    decays: u64,
}

impl Default for ChainSummary {
    fn default() -> Self {
        Self::new()
    }
}

impl ChainSummary {
    pub fn new() -> Self {
        Self {
            bits: vec![0; SUMMARY_BITS / 64],
            prev: vec![0; SUMMARY_BITS / 64],
            inserted: 0,
            decays: 0,
        }
    }

    fn probes(h: u64) -> [(usize, u64); 2] {
        let a = splitmix64(h) as usize % SUMMARY_BITS;
        let b = splitmix64(h ^ PROBE_SALT) as usize % SUMMARY_BITS;
        [(a / 64, 1u64 << (a % 64)), (b / 64, 1u64 << (b % 64))]
    }

    pub fn insert(&mut self, h: u64) {
        for (word, mask) in Self::probes(h) {
            self.bits[word] |= mask;
        }
        self.inserted += 1;
    }

    pub fn contains(&self, h: u64) -> bool {
        Self::probes(h)
            .iter()
            .all(|&(word, mask)| (self.bits[word] | self.prev[word]) & mask != 0)
    }

    /// Age the summary one generation: the previous plane is dropped,
    /// the current plane becomes the previous one, and inserts start
    /// over on a clean plane.  Hashes re-observed since the last decay
    /// stay visible (they sit in the demoted plane); hashes idle for
    /// two whole windows are forgotten, restoring discrimination.
    pub fn decay(&mut self) {
        std::mem::swap(&mut self.bits, &mut self.prev);
        self.bits.iter_mut().for_each(|w| *w = 0);
        self.decays += 1;
    }

    /// Decay generations applied so far (monotone).
    pub fn decays(&self) -> u64 {
        self.decays
    }

    /// Record a routed prompt's full block chain.
    pub fn observe_chain(&mut self, chain: &[u64]) {
        for &h in chain {
            self.insert(h);
        }
    }

    /// Leading blocks of `chain` present in the summary — the affinity
    /// score (cached-prefix depth in blocks, possibly overestimated by
    /// Bloom false positives).
    pub fn score(&self, chain: &[u64]) -> usize {
        chain.iter().take_while(|&&h| self.contains(h)).count()
    }

    /// Total hashes inserted (monotone; duplicates count).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    pub fn clear(&mut self) {
        self.bits.iter_mut().for_each(|w| *w = 0);
        self.prev.iter_mut().for_each(|w| *w = 0);
        self.inserted = 0;
    }
}

/// The routing-time view of one replica, assembled by the registry.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// registry slot (stable for the registry's lifetime)
    pub id: usize,
    /// accepts new admissions (`Alive`, not `Draining`/`Dead`)
    pub admitting: bool,
    /// streams currently open through the gateway
    pub inflight: usize,
    /// requests ever routed here — the rotation tie-break, so idle
    /// ties spread across replicas instead of piling onto slot 0
    pub routed: u64,
    /// leading prompt blocks this replica's summary already holds
    pub score: usize,
}

/// Deterministic replica selection.  With `affinity` on, the admitting
/// replica with the deepest summarized prefix wins; score ties (and the
/// whole decision when `affinity` is off) fall back to least in-flight,
/// then fewest-ever-routed, then lowest id.  `None` when no replica is
/// admitting.
pub fn pick(views: &[ReplicaView], affinity: bool) -> Option<usize> {
    use std::cmp::Reverse;
    let mut best: Option<&ReplicaView> = None;
    for v in views.iter().filter(|v| v.admitting) {
        let better = match best {
            None => true,
            Some(b) => {
                let (vs, bs) = if affinity { (v.score, b.score) } else { (0, 0) };
                (vs, Reverse(v.inflight), Reverse(v.routed), Reverse(v.id))
                    > (bs, Reverse(b.inflight), Reverse(b.routed), Reverse(b.id))
            }
        };
        if better {
            best = Some(v);
        }
    }
    best.map(|v| v.id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain_hashes;

    fn view(id: usize, admitting: bool, inflight: usize, routed: u64, score: usize) -> ReplicaView {
        ReplicaView { id, admitting, inflight, routed, score }
    }

    #[test]
    fn summary_scores_leading_prefix_depth() {
        let prompt: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&prompt, 16);
        assert_eq!(chain.len(), 4);
        let mut s = ChainSummary::new();
        assert_eq!(s.score(&chain), 0);
        s.observe_chain(&chain[..2]);
        assert_eq!(s.score(&chain), 2);
        s.observe_chain(&chain);
        assert_eq!(s.score(&chain), 4);
        // a divergent prompt shares no blocks
        let other: Vec<u32> = (1000..1064).collect();
        assert_eq!(s.score(&chain_hashes(&other, 16)), 0);
        s.clear();
        assert_eq!(s.score(&chain), 0);
        assert_eq!(s.inserted(), 0);
    }

    #[test]
    fn decay_keeps_recent_chains_and_recovers_saturation() {
        let prompt: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&prompt, 16);
        let mut s = ChainSummary::new();

        // One decay must not lose a chain observed in the last window.
        s.observe_chain(&chain);
        s.decay();
        assert_eq!(s.score(&chain), chain.len(), "last-window chains survive one decay");

        // Two idle windows forget it entirely.
        s.decay();
        assert_eq!(s.score(&chain), 0, "idle chains age out after two decays");
        assert_eq!(s.decays(), 2);

        // Saturate: pour in far more distinct hashes than the filter's
        // ~4k-hash capacity until a never-inserted probe false-positives.
        let fresh = chain_hashes(&(9_000_000u32..9_000_064).collect::<Vec<_>>(), 16);
        for i in 0u64..60_000 {
            s.insert(splitmix64(i.wrapping_mul(0x517C_C1B7_2722_0A95)));
        }
        assert!(s.score(&fresh) > 0, "a saturated summary scores everything");

        // Decaying twice retires both stale planes; discrimination is back.
        s.decay();
        s.decay();
        assert_eq!(s.score(&fresh), 0, "decay restores discrimination");

        // And a chain re-observed after the purge still scores full depth.
        s.observe_chain(&chain);
        assert_eq!(s.score(&chain), chain.len());
    }

    #[test]
    fn pick_prefers_score_then_load_then_rotation() {
        // deepest summarized prefix wins over lighter load
        let vs = [view(0, true, 0, 0, 0), view(1, true, 3, 5, 2)];
        assert_eq!(pick(&vs, true), Some(1));
        // affinity off: the same state routes by load alone
        assert_eq!(pick(&vs, false), Some(0));
        // score tie -> least in-flight
        let vs = [view(0, true, 2, 0, 1), view(1, true, 1, 9, 1)];
        assert_eq!(pick(&vs, true), Some(1));
        // full tie -> fewest-ever-routed rotates across idle replicas
        let vs = [view(0, true, 0, 4, 0), view(1, true, 0, 3, 0)];
        assert_eq!(pick(&vs, true), Some(1));
        // non-admitting replicas are invisible, even with the best score
        let vs = [view(0, false, 0, 0, 9), view(1, true, 7, 7, 0)];
        assert_eq!(pick(&vs, true), Some(1));
        assert_eq!(pick(&[view(0, false, 0, 0, 0)], true), None);
    }
}
