//! Network gateway: a dependency-free HTTP/1.1 front end over a
//! replica registry with prefix-affinity routing (docs/gateway.md).
//!
//! * [`http`] — hand-rolled request parsing, chunked streaming
//!   responses, and a minimal blocking client (`std::net` only).
//! * [`affinity`] — per-replica chain-hash Bloom summaries and the
//!   deterministic replica-selection rule.
//! * [`registry`] — replica lifecycle (Alive/Draining/Dead), graceful
//!   drain, metrics aggregation, autoscale hooks.
//!
//! Endpoint contract (full wire details in docs/gateway.md):
//!
//! | endpoint               | behavior                                     |
//! |------------------------|----------------------------------------------|
//! | `POST /v1/generate`    | stream `Event`s as NDJSON over chunked HTTP  |
//! | `GET /healthz`         | fleet admission status (503 when none admit) |
//! | `GET /metrics`         | gateway counters + merged fleet metrics      |
//! | `GET /admin/registry`  | replica table                                |
//! | `POST /admin/drain`    | graceful drain, bounded wait, final health   |
//! | `POST /admin/kill`     | abort a replica (dead-replica failover path) |
//! | `POST /admin/join`     | spawn + register a replica (autoscale hook)  |

pub mod affinity;
pub mod http;
pub mod registry;

pub use affinity::{pick, ChainSummary, ReplicaView};
pub use http::{HttpError, HttpRequest, HttpResponse, NdjsonStream};
pub use registry::{
    InflightGuard, Registry, ReplicaHealth, ReplicaStatus, ScaleHook, ScalePolicy, ScaleSignal,
};

use crate::coordinator::{Completion, Event, FailReason, Request, ServeMetrics};
use crate::jsonutil::Json;
use crate::server::Server;
use http::{ChunkedWriter, HttpRequest as Req};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Builds a fresh replica on demand — the actuation half of the
/// autoscale loop (`POST /admin/join` / a pressure hook calls it).
pub type ReplicaSpawner = Box<dyn FnMut() -> Server + Send>;

#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// serving block size the affinity layer hashes prompts with —
    /// must match the replicas' `ServeConfig::block_size`
    pub block_size: usize,
    /// prefix-affinity routing (false = least-loaded only)
    pub affinity: bool,
    /// per-event wait while streaming; a stream silent this long is
    /// cancelled and failed closed instead of pinning the connection
    pub event_timeout_ms: u64,
    /// bound on the blocking wait inside `POST /admin/drain`
    pub drain_timeout_ms: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            block_size: 16,
            affinity: true,
            event_timeout_ms: 30_000,
            drain_timeout_ms: 10_000,
        }
    }
}

/// Request-outcome counters owned by the gateway itself (replica-side
/// serving metrics live in [`ServeMetrics`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct GatewayCounters {
    pub http_requests: u64,
    pub generate_ok: u64,
    pub generate_failed: u64,
    /// admission rejections (queue full / no admitting replica / ...)
    pub rejected: u64,
    pub drains: u64,
    pub kills: u64,
}

/// The gateway core: registry + routing policy + counters, shared by
/// every connection-handler thread.  [`GatewayServer`] owns the socket.
pub struct Gateway {
    cfg: GatewayConfig,
    registry: Mutex<Registry>,
    counters: Mutex<GatewayCounters>,
    spawner: Mutex<Option<ReplicaSpawner>>,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        Self {
            registry: Mutex::new(Registry::new(cfg.block_size)),
            counters: Mutex::new(GatewayCounters::default()),
            spawner: Mutex::new(None),
            cfg,
        }
    }

    pub fn config(&self) -> &GatewayConfig {
        &self.cfg
    }

    /// Register a replica; returns its id.
    pub fn join(&self, server: Server) -> Option<usize> {
        self.registry.lock().ok().map(|mut reg| reg.join(server))
    }

    /// Install the replica factory `POST /admin/join` invokes.
    pub fn set_spawner(&self, spawner: ReplicaSpawner) {
        if let Ok(mut slot) = self.spawner.lock() {
            *slot = Some(spawner);
        }
    }

    pub fn set_scale_policy(&self, policy: ScalePolicy) {
        if let Ok(mut reg) = self.registry.lock() {
            reg.set_scale_policy(policy);
        }
    }

    pub fn on_pressure(&self, hook: ScaleHook) {
        if let Ok(mut reg) = self.registry.lock() {
            reg.on_pressure(hook);
        }
    }

    /// Begin a graceful drain (non-blocking half; see
    /// [`Gateway::wait_drained`]).
    pub fn drain(&self, id: usize) -> bool {
        let started = self.registry.lock().ok().is_some_and(|mut reg| reg.drain(id));
        if started {
            self.bump(|c| c.drains += 1);
        }
        started
    }

    /// Abort a replica now (dead-replica failover path).
    pub fn kill(&self, id: usize) -> bool {
        let killed = self.registry.lock().ok().is_some_and(|mut reg| reg.kill(id));
        if killed {
            self.bump(|c| c.kills += 1);
        }
        killed
    }

    /// Retire any fully-drained replicas (idempotent sweep).
    pub fn poll_drains(&self) -> Vec<usize> {
        self.registry.lock().ok().map(|mut reg| reg.poll_drains()).unwrap_or_default()
    }

    /// Block until replica `id` leaves `Draining` (its in-flight
    /// streams all closed and its workers shut down), bounded by
    /// `timeout_ms`.  Returns the final health observed (`None` =
    /// unknown id or poisoned registry).
    pub fn wait_drained(&self, id: usize, timeout_ms: u64) -> Option<ReplicaHealth> {
        // analyze: allow(determinism) — the admin drain wait is bounded by a wall-clock deadline by contract
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            let health = match self.registry.lock() {
                Ok(mut reg) => {
                    reg.poll_drains();
                    reg.health(id)
                }
                Err(_) => return None,
            };
            match health {
                Some(ReplicaHealth::Draining) => {}
                other => return other,
            }
            // analyze: allow(determinism) — wall-clock check of the bounded admin-drain deadline
            if Instant::now() >= deadline {
                return health;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Merged fleet metrics (see [`Registry::fleet_metrics`]).
    pub fn fleet_metrics(&self) -> ServeMetrics {
        self.registry.lock().ok().map(|reg| reg.fleet_metrics()).unwrap_or_default()
    }

    pub fn statuses(&self) -> Vec<ReplicaStatus> {
        self.registry.lock().ok().map(|reg| reg.statuses()).unwrap_or_default()
    }

    pub fn counters(&self) -> GatewayCounters {
        self.counters.lock().ok().map(|c| *c).unwrap_or_default()
    }

    /// Feed the autoscale policy one observation of current fleet
    /// pressure (called per generate and per metrics scrape).
    pub fn observe_pressure(&self) {
        if let Ok(mut reg) = self.registry.lock() {
            let p95 = reg.fleet_metrics().streamed_ttft_percentile(95.0);
            reg.observe_pressure(p95);
        }
    }

    fn bump(&self, f: impl FnOnce(&mut GatewayCounters)) {
        if let Ok(mut c) = self.counters.lock() {
            f(&mut c);
        }
    }

    // -- connection handling ------------------------------------------

    fn handle_connection(&self, mut stream: TcpStream) {
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let Ok(read_half) = stream.try_clone() else { return };
        let mut reader = BufReader::new(read_half);
        let req = match http::read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return,
            Err(e) => {
                let status = if matches!(e, HttpError::TooLarge(_)) { 413 } else { 400 };
                respond_json(&mut stream, status, err_json(&e.to_string()));
                return;
            }
        };
        self.bump(|c| c.http_requests += 1);
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => self.handle_generate(&req, &mut stream),
            ("GET", "/healthz") => self.handle_healthz(&mut stream),
            ("GET", "/metrics") => self.handle_metrics(&mut stream),
            ("GET", "/admin/registry") => {
                respond_json(&mut stream, 200, Json::arr(statuses_json(&self.statuses())));
            }
            ("POST", "/admin/drain") => self.handle_drain(&req, &mut stream),
            ("POST", "/admin/kill") => self.handle_kill(&req, &mut stream),
            ("POST", "/admin/join") => self.handle_join(&mut stream),
            _ => respond_json(&mut stream, 404, err_json("no such endpoint")),
        }
    }

    fn handle_generate(&self, req: &Req, stream: &mut TcpStream) {
        let parsed = match parse_generate(&req.body) {
            Ok(parsed) => parsed,
            Err(why) => {
                respond_json(stream, 400, err_json(&why));
                return;
            }
        };
        let submitted = match self.registry.lock() {
            Ok(mut reg) => reg.submit(parsed, self.cfg.affinity),
            Err(_) => {
                respond_json(stream, 500, err_json("registry poisoned"));
                return;
            }
        };
        let (replica, mut handle, guard) = match submitted {
            Ok(triple) => triple,
            Err(e) => {
                self.bump(|c| c.rejected += 1);
                respond_json(stream, 503, err_json(&format!("rejected: {e}")));
                return;
            }
        };
        let Ok(mut w) = ChunkedWriter::begin(&mut *stream, 200, "OK", "application/x-ndjson")
        else {
            handle.cancel();
            return;
        };
        let routed = Json::obj(vec![
            ("event", Json::str("routed")),
            ("replica", Json::Num(replica as f64)),
        ]);
        let mut ok = w.chunk(format!("{}\n", routed.to_string()).as_bytes()).is_ok();
        let mut outcome_ok = false;
        while ok && !handle.is_terminal() {
            match handle.next_timeout(Duration::from_millis(self.cfg.event_timeout_ms)) {
                Some(ev) => {
                    outcome_ok = matches!(ev, Event::Done(_));
                    let line = format!("{}\n", event_json(&ev).to_string());
                    ok = w.chunk(line.as_bytes()).is_ok();
                }
                None => {
                    // silent past the event timeout (stalled replica or
                    // dead worker channel): fail the stream closed
                    handle.cancel();
                    let line = "{\"event\":\"failed\",\"reason\":\"stream_interrupted\"}\n";
                    let _ = w.chunk(line.as_bytes());
                    break;
                }
            }
        }
        if !ok {
            // the client went away mid-stream — release its compute
            handle.cancel();
        }
        let _ = w.finish();
        drop(guard); // stream closed: the drain logic may proceed
        self.bump(|c| {
            if outcome_ok {
                c.generate_ok += 1;
            } else {
                c.generate_failed += 1;
            }
        });
        self.observe_pressure();
    }

    fn handle_healthz(&self, stream: &mut TcpStream) {
        let statuses = self.statuses();
        let admitting =
            statuses.iter().filter(|s| s.health == ReplicaHealth::Alive).count();
        let body = Json::obj(vec![
            ("status", Json::str(if admitting > 0 { "ok" } else { "unavailable" })),
            ("admitting", Json::Num(admitting as f64)),
            ("replicas", Json::arr(statuses_json(&statuses))),
        ]);
        respond_json(stream, if admitting > 0 { 200 } else { 503 }, body);
    }

    fn handle_metrics(&self, stream: &mut TcpStream) {
        self.observe_pressure();
        let c = self.counters();
        let fleet = self.fleet_metrics();
        let body = Json::obj(vec![
            (
                "gateway",
                Json::obj(vec![
                    ("http_requests", Json::Num(c.http_requests as f64)),
                    ("generate_ok", Json::Num(c.generate_ok as f64)),
                    ("generate_failed", Json::Num(c.generate_failed as f64)),
                    ("rejected", Json::Num(c.rejected as f64)),
                    ("drains", Json::Num(c.drains as f64)),
                    ("kills", Json::Num(c.kills as f64)),
                    ("replicas", Json::arr(statuses_json(&self.statuses()))),
                ]),
            ),
            (
                "fleet",
                Json::obj(vec![
                    ("requests_done", Json::Num(fleet.requests_done as f64)),
                    ("tokens_out", Json::Num(fleet.tokens_out as f64)),
                    ("prefix_hits", Json::Num(fleet.prefix_hits as f64)),
                    ("prefix_misses", Json::Num(fleet.prefix_misses as f64)),
                    (
                        "saved_prefill_tokens",
                        Json::Num(fleet.saved_prefill_tokens as f64),
                    ),
                    ("preemptions", Json::Num(fleet.preemptions as f64)),
                    ("cancelled", Json::Num(fleet.cancelled as f64)),
                    ("deadline_missed", Json::Num(fleet.deadline_missed as f64)),
                    ("threads", Json::Num(fleet.threads as f64)),
                    ("ttft_p95_us", Json::num(fleet.ttft_percentile(95.0))),
                    ("tpot_p95_us", Json::num(fleet.tpot_percentile(95.0))),
                    (
                        "streamed_ttft_p95_us",
                        Json::num(fleet.streamed_ttft_percentile(95.0)),
                    ),
                ]),
            ),
        ]);
        respond_json(stream, 200, body);
    }

    fn handle_drain(&self, req: &Req, stream: &mut TcpStream) {
        let Some(id) = parse_replica_id(&req.body) else {
            respond_json(stream, 400, err_json("body must be {\"replica\": <id>}"));
            return;
        };
        let started = self.drain(id);
        let final_health = if started {
            self.wait_drained(id, self.cfg.drain_timeout_ms)
        } else {
            self.registry.lock().ok().and_then(|reg| reg.health(id))
        };
        let Some(health) = final_health else {
            respond_json(stream, 404, err_json("no such replica"));
            return;
        };
        let body = Json::obj(vec![
            ("replica", Json::Num(id as f64)),
            ("started", Json::Bool(started)),
            ("health", Json::str(health.name())),
        ]);
        respond_json(stream, 200, body);
    }

    fn handle_kill(&self, req: &Req, stream: &mut TcpStream) {
        let Some(id) = parse_replica_id(&req.body) else {
            respond_json(stream, 400, err_json("body must be {\"replica\": <id>}"));
            return;
        };
        let killed = self.kill(id);
        let health = self.registry.lock().ok().and_then(|reg| reg.health(id));
        let Some(health) = health else {
            respond_json(stream, 404, err_json("no such replica"));
            return;
        };
        let body = Json::obj(vec![
            ("replica", Json::Num(id as f64)),
            ("killed", Json::Bool(killed)),
            ("health", Json::str(health.name())),
        ]);
        respond_json(stream, 200, body);
    }

    fn handle_join(&self, stream: &mut TcpStream) {
        let server = match self.spawner.lock() {
            Ok(mut slot) => slot.as_mut().map(|spawn| spawn()),
            Err(_) => None,
        };
        let Some(server) = server else {
            respond_json(stream, 409, err_json("no replica spawner configured"));
            return;
        };
        match self.join(server) {
            Some(id) => {
                respond_json(stream, 200, Json::obj(vec![("replica", Json::Num(id as f64))]));
            }
            None => respond_json(stream, 500, err_json("registry poisoned")),
        }
    }
}

/// Serialize one session [`Event`] to its NDJSON object.
pub fn event_json(ev: &Event) -> Json {
    match ev {
        Event::Started => Json::obj(vec![("event", Json::str("started"))]),
        Event::Token { pos, tok } => Json::obj(vec![
            ("event", Json::str("token")),
            ("pos", Json::Num(*pos as f64)),
            ("tok", Json::num(*tok)),
        ]),
        Event::Done(c) => Json::obj(vec![
            ("event", Json::str("done")),
            ("completion", completion_json(c)),
        ]),
        Event::Failed(reason) => {
            let mut pairs = vec![
                ("event", Json::str("failed")),
                ("reason", Json::str(fail_reason_name(reason))),
            ];
            if let Some(partial) = reason.partial() {
                pairs.push(("partial", completion_json(partial)));
            }
            Json::obj(pairs)
        }
    }
}

/// Serialize a [`Completion`] (`ttft_ms`/`total_ms` are `null` when the
/// request never produced a token / never finished).
pub fn completion_json(c: &Completion) -> Json {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("tokens", Json::arr(c.tokens.iter().map(|&t| Json::num(t)))),
        ("ttft_ms", c.ttft_ms.map_or(Json::Null, Json::num)),
        ("total_ms", c.total_ms.map_or(Json::Null, Json::num)),
        ("preemptions", Json::Num(c.preemptions as f64)),
        ("cached_prefix_tokens", Json::Num(c.cached_prefix_tokens as f64)),
    ])
}

fn fail_reason_name(reason: &FailReason) -> &'static str {
    match reason {
        FailReason::Rejected(_) => "rejected",
        FailReason::Cancelled(_) => "cancelled",
        FailReason::DeadlineExceeded(_) => "deadline_exceeded",
        FailReason::WorkerDead => "worker_dead",
        FailReason::TimedOut => "timed_out",
    }
}

/// Parse a `POST /v1/generate` body into a typed [`Request`].
pub fn parse_generate(body: &[u8]) -> Result<Request, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let prompt = j
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'prompt' token array".to_string())?;
    let mut tokens = Vec::with_capacity(prompt.len());
    for t in prompt {
        let Some(v) = t.as_f64() else {
            return Err("non-numeric prompt token".to_string());
        };
        tokens.push(v as u32);
    }
    let mut req = Request::new(tokens);
    if let Some(n) = j.get("max_new").and_then(Json::as_usize) {
        req = req.max_new(n);
    }
    if let Some(s) = j.get("stop").and_then(Json::as_f64) {
        req = req.stop(s as u32);
    }
    if let Some(d) = j.get("deadline_ms").and_then(Json::as_f64) {
        req = req.deadline_ms(d);
    }
    if let Some(p) = j.get("priority").and_then(Json::as_f64) {
        req = req.priority(p as i32);
    }
    if let Some(t) = j.get("tenant").and_then(Json::as_f64) {
        req = req.tenant(t as u32);
    }
    Ok(req)
}

fn parse_replica_id(body: &[u8]) -> Option<usize> {
    let text = std::str::from_utf8(body).ok()?;
    Json::parse(text).ok()?.get("replica")?.as_usize()
}

fn statuses_json(statuses: &[ReplicaStatus]) -> Vec<Json> {
    statuses
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("id", Json::Num(s.id as f64)),
                ("health", Json::str(s.health.name())),
                ("inflight", Json::Num(s.inflight as f64)),
                ("routed", Json::Num(s.routed as f64)),
            ])
        })
        .collect()
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

fn respond_json(stream: &mut TcpStream, status: u16, body: Json) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let text = format!("{}\n", body.to_string());
    let _ = http::write_response(stream, status, reason, "application/json", text.as_bytes());
}

/// A running gateway listener: nonblocking accept loop on its own
/// thread, one handler thread per connection, cooperative stop.
pub struct GatewayServer {
    gateway: Arc<Gateway>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl GatewayServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` — port 0 picks an ephemeral
    /// port, read it back from [`GatewayServer::addr`]) and start
    /// serving `gateway`.
    pub fn bind(addr: &str, gateway: Gateway) -> Result<GatewayServer, HttpError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let gateway = Arc::new(gateway);
        let stop = Arc::new(AtomicBool::new(false));
        let accept_gateway = gateway.clone();
        let accept_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, accept_gateway, accept_stop);
        });
        Ok(GatewayServer { gateway, addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound socket address (`host:port` via `.to_string()`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn gateway(&self) -> Arc<Gateway> {
        self.gateway.clone()
    }

    /// Stop accepting and join the accept loop; in-flight connection
    /// handlers run their streams to completion first.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for GatewayServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(listener: TcpListener, gateway: Arc<Gateway>, stop: Arc<AtomicBool>) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let gw = gateway.clone();
                handlers.push(std::thread::spawn(move || gw.handle_connection(stream)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => break,
        }
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}
