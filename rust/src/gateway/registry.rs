//! The replica registry: N in-process [`Server`] replicas behind one
//! admission surface, with health states, prefix-affinity routing,
//! graceful drain, and autoscale hooks (docs/gateway.md § registry).
//!
//! State machine per replica:
//!
//! ```text
//! join -> Alive -(drain)-> Draining -(in-flight hits 0)-> Dead
//!           \------------(kill: workers aborted)---------/
//! ```
//!
//! `Alive` admits; `Draining` finishes what it has but admits nothing;
//! `Dead` keeps only its merged [`ServeMetrics`] for the fleet view.

use super::affinity::{pick, ChainSummary, ReplicaView};
use crate::coordinator::{chain_hashes, Request, RequestHandle, ServeMetrics, SubmitError};
use crate::server::Server;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Replica lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// serving and admitting
    Alive,
    /// finishing in-flight streams; admits nothing
    Draining,
    /// shut down (drain completed or killed); never admits again
    Dead,
}

impl ReplicaHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaHealth::Alive => "alive",
            ReplicaHealth::Draining => "draining",
            ReplicaHealth::Dead => "dead",
        }
    }
}

/// RAII in-flight marker: the gateway holds one per open stream, and
/// dropping it (stream finished, failed, or client gone) releases the
/// count the drain logic waits on.
pub struct InflightGuard(Arc<AtomicUsize>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

struct Replica {
    server: Option<Server>,
    health: ReplicaHealth,
    summary: ChainSummary,
    inflight: Arc<AtomicUsize>,
    routed: u64,
    /// merged per-worker metrics, captured when the replica retires
    retired: Option<ServeMetrics>,
}

/// A snapshot row of the registry table (admin/introspection surface).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStatus {
    pub id: usize,
    pub health: ReplicaHealth,
    pub inflight: usize,
    pub routed: u64,
}

/// One sustained-pressure observation, passed to the autoscale hook.
#[derive(Debug, Clone, Copy)]
pub struct ScaleSignal {
    /// replicas currently admitting
    pub admitting: usize,
    /// gateway-wide open streams
    pub inflight: usize,
    /// fleet handle-observed TTFT p95 (microseconds) at observation
    pub ttft_p95_us: f64,
    /// consecutive breaching observations that armed the hook
    pub sustained: u32,
}

/// When does pressure count, and how long must it persist.
#[derive(Debug, Clone, Copy)]
pub struct ScalePolicy {
    /// open streams per admitting replica above which an observation
    /// counts as pressure
    pub max_inflight_per_replica: usize,
    /// handle-observed TTFT p95 breach threshold, microseconds
    /// (0 disables the latency trigger)
    pub ttft_p95_us: f64,
    /// consecutive pressure observations before the hook fires
    pub sustain: u32,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        Self { max_inflight_per_replica: 64, ttft_p95_us: 0.0, sustain: 3 }
    }
}

/// Autoscale callback — fired by [`Registry::observe_pressure`] once a
/// breach persists `ScalePolicy::sustain` observations in a row.
pub type ScaleHook = Box<dyn FnMut(&ScaleSignal) + Send>;

/// Routed requests per replica between [`ChainSummary::decay`] calls.
/// A typical chain inserts a handful of block hashes per request, so
/// 1024 routes land well under the summary's ~4k-hash capacity per
/// generation; with two live generations the filter stays far from
/// saturating even on replicas that serve forever.
const SUMMARY_DECAY_EVERY: u64 = 1024;

pub struct Registry {
    replicas: Vec<Replica>,
    /// serving block size the affinity layer hashes prompts with —
    /// must match the replicas' `ServeConfig::block_size`
    block_size: usize,
    policy: ScalePolicy,
    hook: Option<ScaleHook>,
    breaches: u32,
}

impl Registry {
    pub fn new(block_size: usize) -> Self {
        Self {
            replicas: Vec::new(),
            block_size: block_size.max(1),
            policy: ScalePolicy::default(),
            hook: None,
            breaches: 0,
        }
    }

    /// Add a replica; returns its stable id.
    pub fn join(&mut self, server: Server) -> usize {
        self.replicas.push(Replica {
            server: Some(server),
            health: ReplicaHealth::Alive,
            summary: ChainSummary::new(),
            inflight: Arc::new(AtomicUsize::new(0)),
            routed: 0,
            retired: None,
        });
        self.replicas.len() - 1
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn health(&self, id: usize) -> Option<ReplicaHealth> {
        self.replicas.get(id).map(|r| r.health)
    }

    /// Replicas currently admitting new work.
    pub fn admitting(&self) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.health == ReplicaHealth::Alive && r.server.is_some())
            .count()
    }

    pub fn inflight(&self, id: usize) -> usize {
        self.replicas.get(id).map_or(0, |r| r.inflight.load(Ordering::SeqCst))
    }

    pub fn total_inflight(&self) -> usize {
        self.replicas.iter().map(|r| r.inflight.load(Ordering::SeqCst)).sum()
    }

    /// One status row per replica, in id order.
    pub fn statuses(&self) -> Vec<ReplicaStatus> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(id, r)| ReplicaStatus {
                id,
                health: r.health,
                inflight: r.inflight.load(Ordering::SeqCst),
                routed: r.routed,
            })
            .collect()
    }

    pub fn set_scale_policy(&mut self, policy: ScalePolicy) {
        self.policy = policy;
        self.breaches = 0;
    }

    /// Install the autoscale callback (replaces any previous hook).
    pub fn on_pressure(&mut self, hook: ScaleHook) {
        self.hook = Some(hook);
    }

    /// Pick a replica for `prompt` and record the routing decision in
    /// its summary.  `None` when no replica admits.
    pub fn route(&mut self, prompt: &[u32], affinity: bool) -> Option<usize> {
        let chain = chain_hashes(prompt, self.block_size);
        let views: Vec<ReplicaView> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(id, r)| ReplicaView {
                id,
                admitting: r.health == ReplicaHealth::Alive && r.server.is_some(),
                inflight: r.inflight.load(Ordering::SeqCst),
                routed: r.routed,
                score: r.summary.score(&chain),
            })
            .collect();
        let id = pick(&views, affinity)?;
        if let Some(r) = self.replicas.get_mut(id) {
            r.summary.observe_chain(&chain);
            r.routed += 1;
            if r.routed % SUMMARY_DECAY_EVERY == 0 {
                r.summary.decay();
            }
        }
        Some(id)
    }

    /// Route + submit in one step.  The returned [`InflightGuard`] must
    /// live exactly as long as the stream: drain completion waits on it.
    pub fn submit(
        &mut self,
        req: Request,
        affinity: bool,
    ) -> Result<(usize, RequestHandle, InflightGuard), SubmitError> {
        let id = self.route(&req.prompt, affinity).ok_or(SubmitError::WorkerDead)?;
        let Some(r) = self.replicas.get_mut(id) else {
            return Err(SubmitError::WorkerDead);
        };
        let Some(server) = r.server.as_mut() else {
            return Err(SubmitError::WorkerDead);
        };
        let handle = server.submit(req, None)?;
        r.inflight.fetch_add(1, Ordering::SeqCst);
        Ok((id, handle, InflightGuard(r.inflight.clone())))
    }

    /// Begin graceful drain: the replica stops admitting immediately;
    /// in-flight streams keep running.  `true` if the replica was Alive.
    pub fn drain(&mut self, id: usize) -> bool {
        match self.replicas.get_mut(id) {
            Some(r) if r.health == ReplicaHealth::Alive => {
                r.health = ReplicaHealth::Draining;
                true
            }
            _ => false,
        }
    }

    /// Drain every Alive replica (full-fleet retirement).
    pub fn drain_all(&mut self) {
        for id in 0..self.replicas.len() {
            self.drain(id);
        }
    }

    /// Retire each Draining replica whose streams have all closed:
    /// graceful [`Server::shutdown`], per-worker metrics merged and
    /// retained for the fleet view.  Returns the ids retired this call.
    pub fn poll_drains(&mut self) -> Vec<usize> {
        let mut done = Vec::new();
        for (id, r) in self.replicas.iter_mut().enumerate() {
            if r.health == ReplicaHealth::Draining && r.inflight.load(Ordering::SeqCst) == 0 {
                if let Some(server) = r.server.take() {
                    r.retired = Some(ServeMetrics::merge(&server.shutdown()));
                }
                r.health = ReplicaHealth::Dead;
                done.push(id);
            }
        }
        done
    }

    /// Declare a replica dead NOW (crash handling): every worker is
    /// aborted — its in-flight sessions fail with `Cancelled` — and the
    /// registry routes around the slot from this call on.
    pub fn kill(&mut self, id: usize) -> bool {
        let Some(r) = self.replicas.get_mut(id) else {
            return false;
        };
        if r.health == ReplicaHealth::Dead {
            return false;
        }
        if let Some(mut server) = r.server.take() {
            for w in 0..server.workers() {
                server.stop_worker(w);
            }
            r.retired = Some(ServeMetrics::merge(&server.shutdown()));
        }
        r.health = ReplicaHealth::Dead;
        true
    }

    /// One fleet-coherent metrics view: retired replicas' merged
    /// metrics folded together, plus the live replicas' handle-observed
    /// streamed-TTFT collectors (live engine-side counters only become
    /// visible once their replica retires).
    pub fn fleet_metrics(&self) -> ServeMetrics {
        let mut out = ServeMetrics::merge(&[]);
        for r in &self.replicas {
            if let Some(m) = &r.retired {
                out.fold_counters(m);
                if let (Ok(src), Ok(mut dst)) =
                    (m.streamed_ttft_us.lock(), out.streamed_ttft_us.lock())
                {
                    dst.merge(&src);
                }
            }
        }
        for r in &self.replicas {
            if let Some(s) = &r.server {
                let live = s.streamed_ttft();
                if let Ok(mut dst) = out.streamed_ttft_us.lock() {
                    dst.merge(&live);
                }
            }
        }
        out
    }

    /// Record one pressure observation; fires the autoscale hook after
    /// `ScalePolicy::sustain` consecutive breaches, then re-arms.
    pub fn observe_pressure(&mut self, ttft_p95_us: f64) {
        let admitting = self.admitting();
        let inflight = self.total_inflight();
        let queue_breach = inflight > self.policy.max_inflight_per_replica * admitting.max(1);
        let ttft_breach = self.policy.ttft_p95_us > 0.0 && ttft_p95_us > self.policy.ttft_p95_us;
        if queue_breach || ttft_breach {
            self.breaches += 1;
        } else {
            self.breaches = 0;
            return;
        }
        if self.breaches >= self.policy.sustain {
            let signal = ScaleSignal {
                admitting,
                inflight,
                ttft_p95_us,
                sustained: self.breaches,
            };
            self.breaches = 0;
            if let Some(hook) = self.hook.as_mut() {
                hook(&signal);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // These tests cover the pure policy pieces (pressure hook, empty
    // registry); routing/drain against real replicas needs worker
    // threads and lives in tests/gateway.rs.

    #[test]
    fn pressure_hook_fires_only_on_sustained_breach() {
        let mut reg = Registry::new(16);
        reg.set_scale_policy(ScalePolicy {
            max_inflight_per_replica: 0,
            ttft_p95_us: 1000.0,
            sustain: 3,
        });
        let fired: Arc<Mutex<Vec<ScaleSignal>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = fired.clone();
        reg.on_pressure(Box::new(move |s| {
            if let Ok(mut v) = sink.lock() {
                v.push(*s);
            }
        }));
        // two breaches, a recovery, then three sustained breaches
        reg.observe_pressure(5000.0);
        reg.observe_pressure(5000.0);
        reg.observe_pressure(10.0); // resets the streak
        reg.observe_pressure(5000.0);
        reg.observe_pressure(5000.0);
        assert!(fired.lock().unwrap().is_empty());
        reg.observe_pressure(5000.0);
        let seen = fired.lock().unwrap().clone();
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].sustained, 3);
        assert!((seen[0].ttft_p95_us - 5000.0).abs() < 1e-9);
        // the streak re-arms after firing
        reg.observe_pressure(5000.0);
        assert_eq!(fired.lock().unwrap().len(), 1);
    }

    #[test]
    fn empty_registry_admits_nothing() {
        let mut reg = Registry::new(16);
        assert!(reg.is_empty());
        assert_eq!(reg.admitting(), 0);
        assert_eq!(reg.route(&[1, 2, 3], true), None);
        assert!(!reg.drain(0));
        assert!(!reg.kill(0));
        assert!(reg.poll_drains().is_empty());
        let m = reg.fleet_metrics();
        assert_eq!(m.requests_done, 0);
    }
}
