//! Hand-rolled HTTP/1.1 primitives for the gateway: request parsing,
//! fixed and chunked responses, and a minimal blocking client able to
//! consume NDJSON event streams.  `std::net` only — no crates, matching
//! the repo's vendored-stub ethos (docs/gateway.md § wire protocol).
//!
//! Scope is deliberately narrow: one request per connection
//! (`Connection: close` both ways), `Content-Length` bodies on requests,
//! `Content-Length` or `Transfer-Encoding: chunked` on responses.  That
//! is exactly what the gateway's endpoint contract needs and nothing
//! more.

use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Hard cap on the request head (request line + headers).  A hostile or
/// broken peer must not make the gateway buffer without bound.
pub const MAX_HEAD_BYTES: usize = 64 * 1024;

/// Hard cap on request / buffered-response bodies (a 2M-token prompt
/// serialized as JSON fits comfortably).
pub const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;

/// Typed HTTP failure: socket I/O and protocol violations surface as
/// values so a bad peer fails its own connection, never the process.
#[derive(Debug)]
pub enum HttpError {
    /// The underlying socket failed (peer reset, timeout, bind error).
    Io(std::io::Error),
    /// The peer sent bytes that do not parse as the HTTP/1.1 subset.
    Malformed(String),
    /// The head or body exceeds [`MAX_HEAD_BYTES`] / [`MAX_BODY_BYTES`].
    TooLarge(&'static str),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "http i/o error: {e}"),
            HttpError::Malformed(why) => write!(f, "malformed http: {why}"),
            HttpError::TooLarge(what) => write!(f, "http {what} exceeds size cap"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> Self {
        HttpError::Io(e)
    }
}

/// One parsed request.  Header names are lowercased; the target is
/// split at `?` into `path` + `query`.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// raw query string, `""` when absent
    pub query: String,
    /// (lowercased-name, value) pairs in arrival order
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == want).map(|(_, v)| v.as_str())
    }
}

/// Read one `\n`-terminated line (CR stripped) within `budget` bytes.
/// `Ok(None)` is clean EOF before any byte.
fn read_line_capped<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
) -> Result<Option<String>, HttpError> {
    let mut raw = Vec::new();
    let n = r.by_ref().take(*budget as u64 + 1).read_until(b'\n', &mut raw)?;
    if n == 0 {
        return Ok(None);
    }
    if n > *budget {
        return Err(HttpError::TooLarge("head"));
    }
    *budget -= n;
    while raw.last() == Some(&b'\n') || raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw)
        .map(Some)
        .map_err(|_| HttpError::Malformed("non-UTF-8 header line".into()))
}

/// Parse one request off the connection.  `Ok(None)` means the peer
/// closed before sending anything (a normal keepalive-less hangup).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<HttpRequest>, HttpError> {
    let mut budget = MAX_HEAD_BYTES;
    let Some(request_line) = read_line_capped(r, &mut budget)? else {
        return Ok(None);
    };
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(HttpError::Malformed(format!("bad request line '{request_line}'")));
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("unsupported version '{version}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let mut headers = Vec::new();
    loop {
        let Some(line) = read_line_capped(r, &mut budget)? else {
            return Err(HttpError::Malformed("eof inside headers".into()));
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::Malformed(format!("bad header line '{line}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut req = HttpRequest {
        method: method.to_string(),
        path,
        query,
        headers,
        body: Vec::new(),
    };
    if let Some(len) = req.header("content-length") {
        let len: usize = len
            .parse()
            .map_err(|_| HttpError::Malformed(format!("bad content-length '{len}'")))?;
        if len > MAX_BODY_BYTES {
            return Err(HttpError::TooLarge("body"));
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        req.body = body;
    }
    Ok(Some(req))
}

/// Write a complete fixed-length response and flush it.
pub fn write_response(
    w: &mut dyn Write,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// A `Transfer-Encoding: chunked` response in progress: the gateway
/// writes one chunk per NDJSON event line and flushes each, so the
/// client observes tokens as the replica decodes them.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the status line + headers and switch to chunked framing.
    pub fn begin(
        mut w: W,
        status: u16,
        reason: &str,
        content_type: &str,
    ) -> std::io::Result<ChunkedWriter<W>> {
        write!(
            w,
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
             Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
        )?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Emit one chunk (empty input is skipped — a zero-length chunk
    /// would terminate the stream early).
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream with the zero-length chunk.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// A fully-buffered client response (use [`NdjsonStream`] to consume a
/// streamed body event by event instead).
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == want).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 text (empty string on invalid UTF-8).
    pub fn text(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

fn write_request_head(
    w: &mut dyn Write,
    method: &str,
    path: &str,
    body_len: usize,
) -> std::io::Result<()> {
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: gateway\r\nContent-Type: application/json\r\n\
         Content-Length: {body_len}\r\nConnection: close\r\n\r\n"
    )
}

fn read_status_line<R: BufRead>(r: &mut R) -> Result<u16, HttpError> {
    let mut budget = MAX_HEAD_BYTES;
    let Some(line) = read_line_capped(r, &mut budget)? else {
        return Err(HttpError::Malformed("eof before status line".into()));
    };
    let mut parts = line.split(' ');
    match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse()
            .map_err(|_| HttpError::Malformed(format!("bad status code '{code}'"))),
        _ => Err(HttpError::Malformed(format!("bad status line '{line}'"))),
    }
}

fn read_headers<R: BufRead>(r: &mut R) -> Result<Vec<(String, String)>, HttpError> {
    let mut budget = MAX_HEAD_BYTES;
    let mut headers = Vec::new();
    loop {
        let Some(line) = read_line_capped(r, &mut budget)? else {
            return Err(HttpError::Malformed("eof inside headers".into()));
        };
        if line.is_empty() {
            return Ok(headers);
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::Malformed(format!("bad header line '{line}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
}

fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
}

/// Read one chunk-size line and the chunk it frames.  Returns `false`
/// once the terminal zero chunk (and its trailer) has been consumed.
fn read_chunk<R: BufRead>(r: &mut R, into: &mut Vec<u8>) -> Result<bool, HttpError> {
    let mut budget = 1024;
    let Some(size_line) = read_line_capped(r, &mut budget)? else {
        return Err(HttpError::Malformed("eof inside chunked body".into()));
    };
    let size_str = size_line.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .map_err(|_| HttpError::Malformed(format!("bad chunk size '{size_line}'")))?;
    if size == 0 {
        // trailer section: lines until the blank terminator
        let mut tbudget = MAX_HEAD_BYTES;
        while let Some(line) = read_line_capped(r, &mut tbudget)? {
            if line.is_empty() {
                break;
            }
        }
        return Ok(false);
    }
    if size > MAX_BODY_BYTES {
        return Err(HttpError::TooLarge("chunk"));
    }
    let start = into.len();
    into.resize(start + size, 0);
    r.read_exact(&mut into[start..])?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    Ok(true)
}

/// One-shot request over a fresh connection; the response body is
/// buffered in full (chunked bodies are de-framed).
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<HttpResponse, HttpError> {
    let mut stream = TcpStream::connect(addr)?;
    write_request_head(&mut stream, method, path, body.len())?;
    stream.write_all(body)?;
    stream.flush()?;
    let mut r = BufReader::new(stream);
    let status = read_status_line(&mut r)?;
    let headers = read_headers(&mut r)?;
    let mut body = Vec::new();
    if header_value(&headers, "transfer-encoding").is_some_and(|v| v.contains("chunked")) {
        while read_chunk(&mut r, &mut body)? {}
    } else if let Some(len) = header_value(&headers, "content-length") {
        let len: usize = len
            .parse()
            .map_err(|_| HttpError::Malformed(format!("bad content-length '{len}'")))?;
        if len > MAX_BODY_BYTES {
            return Err(HttpError::TooLarge("body"));
        }
        body.resize(len, 0);
        r.read_exact(&mut body)?;
    } else {
        r.by_ref().take(MAX_BODY_BYTES as u64).read_to_end(&mut body)?;
    }
    Ok(HttpResponse { status, headers, body })
}

/// A streaming NDJSON consumer: POSTs a request and yields one line
/// (one event) at a time as the gateway emits chunks, so a test or
/// traffic driver observes the stream with real backpressure.
pub struct NdjsonStream {
    r: BufReader<TcpStream>,
    pub status: u16,
    pub headers: Vec<(String, String)>,
    chunked: bool,
    /// identity-framing bytes still owed (`usize::MAX` = until EOF)
    identity_left: usize,
    eof: bool,
    pending: Vec<u8>,
}

impl NdjsonStream {
    /// POST `body` to `path` and parse the response head; the body is
    /// left on the wire to be pulled via [`NdjsonStream::next_line`].
    pub fn post(addr: &str, path: &str, body: &[u8]) -> Result<NdjsonStream, HttpError> {
        let mut stream = TcpStream::connect(addr)?;
        write_request_head(&mut stream, "POST", path, body.len())?;
        stream.write_all(body)?;
        stream.flush()?;
        let mut r = BufReader::new(stream);
        let status = read_status_line(&mut r)?;
        let headers = read_headers(&mut r)?;
        let chunked =
            header_value(&headers, "transfer-encoding").is_some_and(|v| v.contains("chunked"));
        let identity_left = match header_value(&headers, "content-length") {
            Some(len) => len
                .parse()
                .map_err(|_| HttpError::Malformed(format!("bad content-length '{len}'")))?,
            None => usize::MAX,
        };
        Ok(NdjsonStream {
            r,
            status,
            headers,
            chunked,
            identity_left,
            eof: false,
            pending: Vec::new(),
        })
    }

    fn fill(&mut self) -> Result<(), HttpError> {
        if self.chunked {
            if !read_chunk(&mut self.r, &mut self.pending)? {
                self.eof = true;
            }
            return Ok(());
        }
        let want = self.identity_left.min(4096);
        if want == 0 {
            self.eof = true;
            return Ok(());
        }
        let start = self.pending.len();
        self.pending.resize(start + want, 0);
        let n = self.r.read(&mut self.pending[start..])?;
        self.pending.truncate(start + n);
        if n == 0 {
            self.eof = true;
        } else if self.identity_left != usize::MAX {
            self.identity_left -= n;
        }
        Ok(())
    }

    /// Next non-empty NDJSON line, or `Ok(None)` when the stream ends.
    pub fn next_line(&mut self) -> Result<Option<String>, HttpError> {
        loop {
            if let Some(i) = self.pending.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.pending.drain(..=i).collect();
                let text = String::from_utf8(line)
                    .map_err(|_| HttpError::Malformed("non-UTF-8 ndjson line".into()))?;
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    return Ok(Some(trimmed.to_string()));
                }
                continue;
            }
            if self.eof {
                if self.pending.is_empty() {
                    return Ok(None);
                }
                let line: Vec<u8> = std::mem::take(&mut self.pending);
                let text = String::from_utf8(line)
                    .map_err(|_| HttpError::Malformed("non-UTF-8 ndjson line".into()))?;
                let trimmed = text.trim();
                if trimmed.is_empty() {
                    return Ok(None);
                }
                return Ok(Some(trimmed.to_string()));
            }
            self.fill()?;
        }
    }

    /// Drain the remaining lines into a Vec (convenience for tests).
    pub fn collect_lines(&mut self) -> Result<Vec<String>, HttpError> {
        let mut out = Vec::new();
        while let Some(line) = self.next_line()? {
            out.push(line);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body_and_query() {
        let raw = b"POST /v1/generate?trace=1 HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        let req = read_request(&mut r).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.query, "trace=1");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn empty_connection_is_none_and_garbage_is_malformed() {
        let mut r = BufReader::new(Cursor::new(&b""[..]));
        assert!(read_request(&mut r).unwrap().is_none());
        let mut r = BufReader::new(Cursor::new(&b"what is this\r\n\r\n"[..]));
        assert!(matches!(read_request(&mut r), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn oversized_body_is_rejected_not_buffered() {
        let raw = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let mut r = BufReader::new(Cursor::new(raw.into_bytes()));
        assert!(matches!(read_request(&mut r), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn chunked_writer_round_trips_through_chunk_reader() {
        let mut wire = Vec::new();
        let mut w = ChunkedWriter::begin(&mut wire, 200, "OK", "application/x-ndjson").unwrap();
        w.chunk(b"{\"event\":\"started\"}\n").unwrap();
        w.chunk(b"").unwrap(); // skipped, must not terminate
        w.chunk(b"{\"event\":\"done\"}\n").unwrap();
        w.finish().unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        let mut r = BufReader::new(Cursor::new(&wire[body_at..]));
        let mut body = Vec::new();
        while read_chunk(&mut r, &mut body).unwrap() {}
        assert_eq!(
            String::from_utf8(body).unwrap(),
            "{\"event\":\"started\"}\n{\"event\":\"done\"}\n"
        );
    }
}
