//! Persistent worker pool behind the deterministic parallel engine tick.
//!
//! std::thread + channels only (no external crates): `threads` workers
//! pull boxed jobs off one shared channel and run them.  [`WorkerPool::run`]
//! is a *scoped* batch submit — it blocks until every job of the batch has
//! finished, which is what makes handing the jobs borrowed data sound (the
//! borrows cannot outlive the call; see the safety note in `run`).
//!
//! Determinism: the pool imposes no ordering of its own.  Callers obtain
//! bitwise-reproducible results by handing each job a *disjoint* output
//! slot (no cross-job reduction) and folding any shared accounting back
//! on the caller thread in a fixed order — exactly how
//! [`crate::model::Model::decode_batch`] shards its per-(sequence, KV-head)
//! attention work.  See `docs/perf.md`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A batch job borrowing data from the submitting scope ([`WorkerPool::run`]).
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` persistent workers (min 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
                std::thread::Builder::new()
                    .name(format!("kascade-worker-{i}"))
                    .spawn(move || loop {
                        // the textbook shared-receiver pattern: hold the
                        // lock only across the blocking recv
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break,
                        };
                        match job {
                            // a panicking job must not kill the worker:
                            // the DoneGuard reports it to the submitter
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of jobs to completion on the pool, blocking until the
    /// last one finishes.  A job that panicked re-panics here, on the
    /// submitting thread.
    ///
    /// Safety of the lifetime erasure below: the jobs may borrow from the
    /// caller's scope (`'env`).  Each job is wrapped so that a completion
    /// token is sent on a private channel even if it panics (via the
    /// `DoneGuard` drop), and this function does not return until it has
    /// received exactly one token per job — so every borrow handed to a
    /// worker provably ends before `run` returns, which is the invariant
    /// `'env: 'static` erasure needs.  (This is the standard scoped-pool
    /// construction; std::thread::scope cannot be used here because the
    /// workers are persistent across calls.)
    pub fn run<'env>(&self, jobs: Vec<ScopedJob<'env>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let (done_tx, done_rx) = channel::<bool>();
        let tx = self.tx.as_ref().expect("worker pool is live");
        for job in jobs {
            // lifetime erasure, justified by the completion barrier below
            let job: Job = unsafe { std::mem::transmute::<ScopedJob<'env>, Job>(job) };
            let done = done_tx.clone();
            tx.send(Box::new(move || {
                let mut guard = DoneGuard { tx: done, ok: false };
                job();
                guard.ok = true;
            }))
            .expect("worker pool hung up");
        }
        let mut ok = true;
        for _ in 0..n {
            ok &= done_rx.recv().expect("pool worker died mid-batch");
        }
        assert!(ok, "a worker-pool job panicked");
    }
}

/// Sends the job's completion token even when the job unwinds.
struct DoneGuard {
    tx: Sender<bool>,
    ok: bool,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(self.ok);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0u64; 64];
        for round in 1..4u64 {
            let jobs: Vec<ScopedJob<'_>> = out
                .chunks_mut(7)
                .enumerate()
                .map(|(i, chunk)| {
                    let f: ScopedJob<'_> = Box::new(move || {
                        for (j, x) in chunk.iter_mut().enumerate() {
                            *x += round * (i * 100 + j) as u64;
                        }
                    });
                    f
                })
                .collect();
            pool.run(jobs);
        }
        // 1x + 2x + 3x = 6x of the per-slot constant
        for (i, &x) in out.iter().enumerate() {
            let slot = ((i / 7) * 100 + i % 7) as u64;
            assert_eq!(x, 6 * slot, "slot {i}");
        }
    }

    #[test]
    fn empty_batch_is_a_noop_and_pool_drops_clean() {
        let pool = WorkerPool::new(2);
        pool.run(Vec::new());
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob<'_>> = (0..8)
            .map(|_| {
                let f: ScopedJob<'_> = Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                f
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        drop(pool); // joins workers without hanging
    }

    #[test]
    #[should_panic(expected = "worker-pool job panicked")]
    fn job_panic_surfaces_on_the_submitter() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<ScopedJob<'_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.run(jobs);
    }

    /// A panicked batch must not poison the pool: the workers survive
    /// (the panic is caught per job), a later batch runs normally, and
    /// `Drop` still joins every worker without hanging.
    #[test]
    fn pool_survives_a_panicked_batch_and_shuts_down_clean() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.size(), 2);
        let unwound = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<ScopedJob<'_>> =
                vec![Box::new(|| panic!("boom")), Box::new(|| {}), Box::new(|| panic!("boom"))];
            pool.run(jobs);
        }));
        assert!(unwound.is_err(), "run must re-panic on the submitter");
        // every worker is still alive and pulling jobs
        assert_eq!(pool.size(), 2);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob<'_>> = (0..16)
            .map(|_| {
                let f: ScopedJob<'_> = Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                f
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        drop(pool); // channel closes, both workers join
    }
}
