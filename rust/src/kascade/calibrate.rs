//! Offline calibration pipeline (the paper's deployment recipe, Sec. 3.3):
//! run a development set through the model with dense attention, capture
//! pooled distributions + importance samples, build the similarity matrix
//! (Eq. 3), select anchors with Algorithm 1, and derive head maps.

use super::anchor_select::select_anchors;
use super::headmap::build_head_maps;
use super::plan::{segment_map, KascadePlan};
use super::similarity::SimilarityBuilder;
use crate::config::TopKRule;
use crate::model::{CaptureRequest, Model};
use crate::sparse::DensePolicy;

pub struct CalibrateOptions {
    /// Anchor budget M (paper: 5).
    pub anchors: usize,
    /// Top-k used inside the similarity statistic (paper: 64).
    pub sim_k: usize,
    /// Probe positions per prompt (late positions; min over them drives the
    /// conservative layer similarity).
    pub probes_per_prompt: usize,
    /// Serve-time Top-k rule recorded in the plan.
    pub topk: TopKRule,
    /// Apply the importance weighting `S[i][j] *= w_j` (Sec. 3.3).
    pub weight_by_importance: bool,
}

impl Default for CalibrateOptions {
    fn default() -> Self {
        Self {
            anchors: 5,
            sim_k: 64,
            probes_per_prompt: 6,
            topk: TopKRule::default(),
            weight_by_importance: true,
        }
    }
}

/// Calibration result: the deployable plan plus the raw statistics (used
/// by the eval drivers to regenerate Figs. 3 and 4).
pub struct Calibration {
    pub plan: KascadePlan,
    pub sim: SimilarityBuilder,
    pub importance: Vec<f32>,
}

/// Run the full pipeline over `dev_prompts`.
pub fn calibrate(model: &Model, dev_prompts: &[Vec<u32>], opts: &CalibrateOptions) -> Calibration {
    let cfg = &model.cfg;
    let mut sim = SimilarityBuilder::new(cfg.n_layers, cfg.n_kv_heads, opts.sim_k);
    for prompt in dev_prompts {
        let n = prompt.len();
        // probe the final positions (incl. the query token) plus a few
        // interior ones for coverage
        let mut probes: Vec<usize> = (0..opts.probes_per_prompt / 2)
            .map(|i| n - 1 - i)
            .filter(|&p| p > 0)
            .collect();
        let stride = n / (opts.probes_per_prompt / 2 + 1).max(1);
        for i in 1..=(opts.probes_per_prompt - probes.len()) {
            let p = (i * stride).min(n - 1);
            if !probes.contains(&p) {
                probes.push(p);
            }
        }
        let mut st = model.new_state(n + 8);
        let req = CaptureRequest { probe_positions: probes };
        let (_, cap) = model.prefill(prompt, &mut st, &mut DensePolicy, Some(&req));
        sim.add_prompt(&cap.unwrap());
    }
    let importance = sim.importance();
    let matrix = sim.layer_matrix(opts.weight_by_importance);
    let (anchors, objective) = select_anchors(&matrix, opts.anchors);
    let head_map = build_head_maps(&sim, cfg.n_layers, &anchors);
    let mut plan = KascadePlan {
        n_layers: cfg.n_layers,
        n_kv_heads: cfg.n_kv_heads,
        segment_of: segment_map(cfg.n_layers, &anchors),
        anchors,
        head_map,
        topk: opts.topk,
        objective,
    };
    if plan.anchors.first() != Some(&0) {
        // defensive: Algorithm 1 always starts its first segment at 0
        plan.anchors.insert(0, 0);
        plan.segment_of = segment_map(cfg.n_layers, &plan.anchors);
    }
    plan.validate().expect("calibration produced invalid plan");
    Calibration { plan, sim, importance }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SynthSpec;
    use crate::workload::WorkloadGen;

    fn spec() -> SynthSpec {
        let mut s = SynthSpec::eval_base(5);
        s.cfg.n_layers = 8;
        s.block_starts = vec![1, 4];
        s
    }

    fn dev_prompts(spec: &SynthSpec, n: usize, ctx: usize) -> Vec<Vec<u32>> {
        let mut gen = WorkloadGen::new(spec, 77);
        (0..n).map(|_| gen.dev_prompt(ctx)).collect()
    }

    /// End-to-end: calibration on the planted-block SynthLM must place
    /// anchors at (or adjacent to) the planted block starts, find the
    /// permuted match-head mapping, and produce decaying importance.
    #[test]
    fn calibration_recovers_planted_structure() {
        let spec = spec();
        let model = spec.build();
        let prompts = dev_prompts(&spec, 3, 256);
        // unweighted: pure cross-layer similarity should recover the
        // planted blocks {0}, {1..3}, {4..7}
        let opts = CalibrateOptions {
            anchors: 3,
            sim_k: 16,
            weight_by_importance: false,
            ..Default::default()
        };
        let cal = calibrate(&model, &prompts, &opts);
        assert_eq!(cal.plan.anchors.len(), 3);
        assert_eq!(cal.plan.anchors[0], 0);
        assert!(
            cal.plan.anchors[1] <= 2,
            "second anchor {} should sit at planted block 1",
            cal.plan.anchors[1]
        );
        assert!(
            (3..=5).contains(&cal.plan.anchors[2]),
            "third anchor {} should sit near planted block 4",
            cal.plan.anchors[2]
        );

        // importance decays from the first match block to the last layer
        assert!(
            cal.importance[1] > cal.importance[7],
            "importance should decay: {:?}",
            cal.importance
        );
        cal.plan.validate().unwrap();

        // importance weighting (the paper default) can only pull anchors
        // toward the high-importance early layers
        let wopts = CalibrateOptions { anchors: 3, sim_k: 16, ..Default::default() };
        let wcal = calibrate(&model, &prompts, &wopts);
        assert_eq!(wcal.plan.anchors[0], 0);
        assert!(
            wcal.plan.anchors[2] <= cal.plan.anchors[2],
            "weighted anchors {:?} should not sit deeper than unweighted {:?}",
            wcal.plan.anchors,
            cal.plan.anchors
        );
    }

    /// With head remapping, a reuse layer's match head must map to the
    /// anchor's match head even though slots are permuted.
    #[test]
    fn head_maps_track_the_match_head() {
        let spec = spec();
        let model = spec.build();
        let prompts = dev_prompts(&spec, 2, 256);
        let opts = CalibrateOptions { anchors: 2, sim_k: 16, ..Default::default() };
        let cal = calibrate(&model, &prompts, &opts);

        // locate the match slot per layer from the generator's wiring
        let dh = spec.cfg.d_head;
        let match_slot = |l: usize| -> usize {
            let lw = &model.w.layers[l];
            (0..spec.cfg.n_kv_heads)
                .max_by(|&a, &b| {
                    let diag = |s: usize| -> f32 {
                        (0..dh)
                            .map(|j| lw.wk[(dh + j) * spec.cfg.n_kv_heads * dh + s * dh + j].abs())
                            .sum()
                    };
                    diag(a).partial_cmp(&diag(b)).unwrap()
                })
                .unwrap()
        };
        let mut checked = 0;
        for l in 0..spec.cfg.n_layers {
            let a = cal.plan.segment_of[l];
            if a == l || a == 0 {
                continue; // anchor itself, or layer-0 anchor (no match head)
            }
            let (ms_l, ms_a) = (match_slot(l), match_slot(a));
            assert_eq!(
                cal.plan.head_map[l][ms_l],
                ms_a,
                "layer {l} match slot {ms_l} should map to anchor {a} slot {ms_a}"
            );
            checked += 1;
        }
        assert!(checked > 0, "no reuse layers exercised");
    }
}
