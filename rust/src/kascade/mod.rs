//! The paper's contribution: cross-layer Top-k reuse.
//!
//! * [`similarity`] — Eq. 3 cross-layer (and cross-head) similarity from
//!   captured attention distributions, min-over-tokens / mean-over-prompts,
//!   plus the importance weights `w_l = 1 - cos(x_l, y_l)` (Sec. 3.3).
//! * [`anchor_select`] — Algorithm 1: dynamic-programming anchor-layer
//!   selection over the weighted similarity matrix.
//! * [`headmap`] — head remapping (Sec. 3.5): reuse-layer head -> most
//!   similar anchor-layer head (many-to-one).
//! * [`plan`] — the deployable `KascadePlan` artifact (JSON) consumed by
//!   the serving coordinator and the native engine policy.

pub mod anchor_select;
pub mod calibrate;
pub mod headmap;
pub mod plan;
pub mod similarity;

pub use anchor_select::select_anchors;
pub use calibrate::{calibrate, CalibrateOptions, Calibration};
pub use headmap::build_head_maps;
pub use plan::{KascadePlan, LayerRole};
pub use similarity::{CalibrationCapture, SimilarityBuilder};
