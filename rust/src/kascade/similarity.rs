//! Cross-layer similarity (Eq. 3) and layer importance (Sec. 3.3) from
//! captured attention distributions on a development set.
//!
//! For each probed query token the capture stores, at every layer, the
//! post-softmax **pooled** distribution of every KV head (i.e. exactly what
//! the anchor kernels pool at serve time — the "incorporates the
//! modifications of Sec. 3.4/3.5" requirement), plus the attention-block
//! importance sample `1 - cos(x_l, y_l)`.
//!
//! The builder aggregates:
//! * head-level similarity `sim(a, ha -> b, hb)` — mean over tokens and
//!   prompts (drives head remapping),
//! * layer-level similarity `S[a][b]` — per-prompt **minimum over tokens**
//!   of the head-remapped layer similarity (the paper's conservative
//!   choice), then mean over prompts,
//! * importance weights `w_l` — mean of `1 - cos(x_l, y_l)`.

use super::anchor_select::SimMatrix;
use crate::tensor::topk_indices;

/// Distributions and importance samples for one probed query token.
pub struct ProbeCapture {
    /// `dists[layer][kv_head]` = pooled post-softmax distribution over the
    /// context visible at this token.
    pub dists: Vec<Vec<Vec<f32>>>,
    /// `importance[layer]` = `1 - cos(x_l, y_l)` at this token.
    pub importance: Vec<f32>,
}

/// All probes captured from one development prompt.
pub struct CalibrationCapture {
    pub n_layers: usize,
    pub n_kv: usize,
    pub probes: Vec<ProbeCapture>,
}

/// Accumulates Eq.-3 statistics across development prompts.
pub struct SimilarityBuilder {
    pub n_layers: usize,
    pub n_kv: usize,
    /// Top-k size used for the similarity statistic (paper: 64).
    pub k: usize,
    head_sum: Vec<f64>, // [a][b][ha][hb], a <= b
    head_cnt: u64,
    layer_sum: Vec<f64>, // [a][b] sum over prompts of min-over-tokens
    n_prompts: u64,
    imp_sum: Vec<f64>,
    imp_cnt: u64,
}

impl SimilarityBuilder {
    pub fn new(n_layers: usize, n_kv: usize, k: usize) -> Self {
        Self {
            n_layers,
            n_kv,
            k,
            head_sum: vec![0.0; n_layers * n_layers * n_kv * n_kv],
            head_cnt: 0,
            layer_sum: vec![0.0; n_layers * n_layers],
            n_prompts: 0,
            imp_sum: vec![0.0; n_layers],
            imp_cnt: 0,
        }
    }

    #[inline]
    fn hidx(&self, a: usize, b: usize, ha: usize, hb: usize) -> usize {
        ((a * self.n_layers + b) * self.n_kv + ha) * self.n_kv + hb
    }

    /// Recovered-mass ratio: how much of `target`'s own top-k mass the
    /// index set `idx` captures (Eq. 3 numerator / denominator).
    fn recovery(&self, idx: &[u32], target: &[f32]) -> f32 {
        let own: f32 = topk_indices(target, self.k.min(target.len()))
            .iter()
            .map(|&i| target[i as usize])
            .sum();
        if own <= 0.0 {
            return 1.0;
        }
        let got: f32 = idx
            .iter()
            .filter(|&&i| (i as usize) < target.len())
            .map(|&i| target[i as usize])
            .sum();
        (got / own).min(1.0)
    }

    pub fn add_prompt(&mut self, cap: &CalibrationCapture) {
        assert_eq!(cap.n_layers, self.n_layers);
        assert_eq!(cap.n_kv, self.n_kv);
        let nl = self.n_layers;
        let nk = self.n_kv;
        // per-prompt min over tokens of the layer-level similarity
        let mut layer_min = vec![f32::INFINITY; nl * nl];
        for probe in &cap.probes {
            // top-k index sets per (layer, head)
            let idx: Vec<Vec<Vec<u32>>> = probe
                .dists
                .iter()
                .map(|heads| {
                    heads
                        .iter()
                        .map(|d| topk_indices(d, self.k.min(d.len())))
                        .collect()
                })
                .collect();
            for a in 0..nl {
                for b in a..nl {
                    // head-level recoveries
                    let mut layer_acc = 0.0f32;
                    for hb in 0..nk {
                        let target = &probe.dists[b][hb];
                        let mut best = 0.0f32;
                        for ha in 0..nk {
                            let r = self.recovery(&idx[a][ha], target);
                            let hi = self.hidx(a, b, ha, hb);
                            self.head_sum[hi] += r as f64;
                            if r > best {
                                best = r;
                            }
                        }
                        layer_acc += best;
                    }
                    let sim = layer_acc / nk as f32;
                    let cell = &mut layer_min[a * nl + b];
                    if sim < *cell {
                        *cell = sim;
                    }
                }
            }
            for (l, &w) in probe.importance.iter().enumerate() {
                self.imp_sum[l] += w as f64;
            }
            self.imp_cnt += 1;
            self.head_cnt += 1;
        }
        if !cap.probes.is_empty() {
            for (sum, &mn) in self.layer_sum.iter_mut().zip(layer_min.iter()) {
                if mn.is_finite() {
                    *sum += mn as f64;
                }
            }
            self.n_prompts += 1;
        }
    }

    /// Mean head-level similarity `a.ha -> b.hb`.
    pub fn head_similarity(&self, a: usize, b: usize, ha: usize, hb: usize) -> f32 {
        if self.head_cnt == 0 {
            return 0.0;
        }
        (self.head_sum[self.hidx(a, b, ha, hb)] / self.head_cnt as f64) as f32
    }

    /// Mean importance weights `w_l`.
    pub fn importance(&self) -> Vec<f32> {
        self.imp_sum
            .iter()
            .map(|&s| if self.imp_cnt == 0 { 1.0 } else { (s / self.imp_cnt as f64) as f32 })
            .collect()
    }

    /// Layer-level similarity matrix; `weighted` applies `S[i][j] *= w_j`.
    pub fn layer_matrix(&self, weighted: bool) -> SimMatrix {
        let nl = self.n_layers;
        let mut s = SimMatrix::new(nl);
        if self.n_prompts > 0 {
            for a in 0..nl {
                for b in a..nl {
                    s.set(a, b, (self.layer_sum[a * nl + b] / self.n_prompts as f64) as f32);
                }
            }
        }
        if weighted {
            s.weight_columns(&self.importance());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// Synthetic capture: all layers share one peaked distribution except
    /// layer `odd_layer`, which is independent; head 1 of every layer is a
    /// permuted copy of head 0 of layer 0.
    fn synth_capture(n_layers: usize, odd_layer: usize) -> CalibrationCapture {
        let n_kv = 2;
        let len = 256;
        let mut rng = Rng::new(9);
        let mut base = vec![0.0f32; len];
        for i in 0..len {
            base[i] = if i % 11 == 0 { 1.0 } else { 0.001 * rng.uniform() };
        }
        let norm: f32 = base.iter().sum();
        base.iter_mut().for_each(|x| *x /= norm);
        let mut shifted = base.clone();
        shifted.rotate_right(3); // "permuted head": peaks at different keys
        let mut odd = vec![0.0f32; len];
        for i in 0..len {
            odd[i] = if i % 7 == 3 { 1.0 } else { 0.0005 };
        }
        let n: f32 = odd.iter().sum();
        odd.iter_mut().for_each(|x| *x /= n);

        let probes = (0..4)
            .map(|_| ProbeCapture {
                dists: (0..n_layers)
                    .map(|l| {
                        if l == odd_layer {
                            vec![odd.clone(), odd.clone()]
                        } else {
                            // head 0 = base, head 1 = shifted (same for all
                            // layers -> cross-layer head identity holds
                            // under the map 0->0, 1->1)
                            vec![base.clone(), shifted.clone()]
                        }
                    })
                    .collect(),
                importance: (0..n_layers).map(|l| 1.0 / (1.0 + l as f32)).collect(),
            })
            .collect();
        CalibrationCapture { n_layers, n_kv, probes }
    }

    #[test]
    fn identical_layers_have_similarity_one() {
        let mut b = SimilarityBuilder::new(4, 2, 16);
        b.add_prompt(&synth_capture(4, 99));
        let s = b.layer_matrix(false);
        for a in 0..4 {
            for j in a..4 {
                assert!(s.get(a, j) > 0.99, "S[{a}][{j}] = {}", s.get(a, j));
            }
        }
    }

    #[test]
    fn odd_layer_has_low_similarity() {
        let mut b = SimilarityBuilder::new(4, 2, 16);
        b.add_prompt(&synth_capture(4, 2));
        let s = b.layer_matrix(false);
        assert!(s.get(0, 1) > 0.95);
        assert!(s.get(0, 2) < 0.5, "S[0][2] = {}", s.get(0, 2));
        assert!(s.get(2, 3) < 0.5);
        // diagonal stays 1 even for the odd layer
        assert!(s.get(2, 2) > 0.99);
    }

    #[test]
    fn head_similarity_identifies_matching_head() {
        let mut b = SimilarityBuilder::new(3, 2, 16);
        b.add_prompt(&synth_capture(3, 99));
        // head 0 <-> head 0 strong; head 0 -> head 1 weak
        assert!(b.head_similarity(0, 1, 0, 0) > 0.95);
        assert!(b.head_similarity(0, 1, 1, 1) > 0.95);
        assert!(b.head_similarity(0, 1, 0, 1) < 0.6);
    }

    #[test]
    fn importance_is_mean_of_samples() {
        let mut b = SimilarityBuilder::new(4, 2, 16);
        b.add_prompt(&synth_capture(4, 99));
        let w = b.importance();
        for (l, &wl) in w.iter().enumerate() {
            assert!((wl - 1.0 / (1.0 + l as f32)).abs() < 1e-5);
        }
    }

    #[test]
    fn weighted_matrix_scales_columns() {
        let mut b = SimilarityBuilder::new(4, 2, 16);
        b.add_prompt(&synth_capture(4, 99));
        let unw = b.layer_matrix(false);
        let wtd = b.layer_matrix(true);
        let w = b.importance();
        for a in 0..4 {
            for j in a..4 {
                assert!((wtd.get(a, j) - unw.get(a, j) * w[j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn empty_builder_yields_zero_matrix() {
        let b = SimilarityBuilder::new(4, 2, 16);
        let s = b.layer_matrix(true);
        assert!(s.data.iter().all(|&x| x == 0.0));
    }
}
