//! Algorithm 1: dynamic-programming anchor-layer selection.
//!
//! Given the (importance-weighted) cross-layer similarity matrix `S`
//! (`S[i][j]` = how much of layer `j`'s oracle Top-k mass the Top-k of
//! layer `i` recovers, `i <= j`), choose `M` anchors that partition the
//! layer range into contiguous segments, each led by its anchor, maximizing
//!
//! ```text
//! sum over segments [a_m, a_{m+1})  of  sum_{l in segment} S[a_m][l]
//! ```

/// Row-major square matrix helper.
#[derive(Debug, Clone)]
pub struct SimMatrix {
    pub n: usize,
    pub data: Vec<f32>,
}

impl SimMatrix {
    pub fn new(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.n + j] = v;
    }

    /// Apply importance weights: `S[i][j] *= w[j]` (Sec. 3.3).
    pub fn weight_columns(&mut self, w: &[f32]) {
        assert_eq!(w.len(), self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                self.data[i * self.n + j] *= w[j];
            }
        }
    }
}

/// Returns (anchors sorted ascending, objective value).
///
/// `m` is the anchor budget.  Layer 0 is always the first anchor (the DP's
/// first segment necessarily starts at layer 0, matching the paper where
/// layer 0 runs dense and anchors the first segment).
pub fn select_anchors(s: &SimMatrix, m: usize) -> (Vec<usize>, f32) {
    let n = s.n;
    let m = m.clamp(1, n);
    // prefix[i][j] = sum_{l=i}^{j} S[i][l]
    // dp[seg][j] = best objective covering layers 0..=j-1 with `seg` segments
    let neg = f32::NEG_INFINITY;
    let mut dp = vec![vec![neg; n + 1]; m + 1];
    let mut path = vec![vec![0usize; n + 1]; m + 1];
    // segment cost: anchor at i covering layers i..j-1 (inclusive)
    let seg_cost = |i: usize, j: usize| -> f32 {
        (i..j).map(|l| s.get(i, l)).sum()
    };
    dp[0][0] = 0.0;
    for seg in 1..=m {
        for j in seg..=n {
            // last segment starts at i (its anchor), i ranges over
            // [seg-1, j-1]; previous segments cover 0..i-1.
            let mut best = neg;
            let mut arg = 0;
            for i in (seg - 1)..j {
                let prev = dp[seg - 1][i];
                if prev == neg {
                    continue;
                }
                let v = prev + seg_cost(i, j);
                if v > best {
                    best = v;
                    arg = i;
                }
            }
            dp[seg][j] = best;
            path[seg][j] = arg;
        }
    }
    // Fewer segments can never beat more segments here (S entries >= 0 and
    // S[i][i] is maximal), but pick the best m' <= m defensively.
    let mut best_m = m;
    for cand in 1..=m {
        if dp[cand][n] > dp[best_m][n] {
            best_m = cand;
        }
    }
    let mut anchors = Vec::with_capacity(best_m);
    let mut j = n;
    for seg in (1..=best_m).rev() {
        let i = path[seg][j];
        anchors.push(i);
        j = i;
    }
    anchors.reverse();
    (anchors, dp[best_m][n])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Similarity with planted blocks: S[i][j] = 1 - 0.2 * (j - i) within a
    /// block, near zero across blocks.
    fn planted(n: usize, starts: &[usize]) -> SimMatrix {
        let block_of = |l: usize| starts.iter().rposition(|&s| s <= l).unwrap();
        let mut s = SimMatrix::new(n);
        for i in 0..n {
            for j in i..n {
                let v = if block_of(i) == block_of(j) {
                    (1.0 - 0.1 * (j - i) as f32).max(0.0)
                } else {
                    0.05
                };
                s.set(i, j, v);
            }
        }
        s
    }

    #[test]
    fn recovers_planted_block_starts() {
        let starts = vec![0, 3, 7, 12];
        let s = planted(16, &starts);
        let (anchors, obj) = select_anchors(&s, 4);
        assert_eq!(anchors, starts);
        assert!(obj > 0.0);
    }

    #[test]
    fn first_anchor_is_layer_zero() {
        let s = planted(8, &[0, 4]);
        for m in 1..=4 {
            let (anchors, _) = select_anchors(&s, m);
            assert_eq!(anchors[0], 0, "m={m}");
        }
    }

    #[test]
    fn budget_one_selects_only_layer_zero() {
        let s = planted(8, &[0, 4]);
        let (anchors, _) = select_anchors(&s, 1);
        assert_eq!(anchors, vec![0]);
    }

    #[test]
    fn objective_nondecreasing_in_budget() {
        let s = planted(16, &[0, 5, 9]);
        let mut prev = f32::NEG_INFINITY;
        for m in 1..=8 {
            let (_, obj) = select_anchors(&s, m);
            assert!(obj >= prev - 1e-5, "m={m}: {obj} < {prev}");
            prev = obj;
        }
    }

    #[test]
    fn anchors_sorted_unique_and_within_range() {
        let s = planted(12, &[0, 2, 6]);
        let (anchors, _) = select_anchors(&s, 5);
        let mut sorted = anchors.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(anchors, sorted);
        assert!(anchors.iter().all(|&a| a < 12));
    }

    #[test]
    fn importance_weighting_shifts_anchors_toward_heavy_layers() {
        // uniform similarity; importance concentrated on early layers
        let n = 8;
        let mut s = SimMatrix::new(n);
        for i in 0..n {
            for j in i..n {
                s.set(i, j, 1.0 - 0.05 * (j - i) as f32);
            }
        }
        let mut weighted = s.clone();
        let w: Vec<f32> = (0..n).map(|l| if l < 4 { 1.0 } else { 0.01 }).collect();
        weighted.weight_columns(&w);
        let (a_unw, _) = select_anchors(&s, 3);
        let (a_wtd, _) = select_anchors(&weighted, 3);
        // weighted run should spend its anchors on the first half
        assert!(a_wtd.iter().filter(|&&a| a < 4).count() >= a_unw.iter().filter(|&&a| a < 4).count());
        assert!(a_wtd[2] <= 4);
    }
}
