//! The deployable Kascade plan: which layers are anchors, which anchor each
//! reuse layer reads from, and the per-layer head remapping.

use crate::config::TopKRule;
use crate::jsonutil::Json;
use std::path::Path;

/// Role of a layer in the serve-time schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerRole {
    /// Layer 0 when it is an anchor: dense attention + Top-k extraction
    /// (paper Sec. 3.1 — layer 0's distribution is too flat to sparsify).
    Anchor0,
    /// Anchor layer: multi-pass Top-k extraction + sparse attention.
    Anchor,
    /// Reuse layer: sparse attention over the given anchor's indices.
    Reuse { anchor: usize },
}

/// Calibrated, model-specific Kascade deployment artifact.
#[derive(Debug, Clone)]
pub struct KascadePlan {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    /// Sorted anchor layers; always contains 0.
    pub anchors: Vec<usize>,
    /// `segment_of[l]` = the anchor layer whose indices layer `l` uses.
    pub segment_of: Vec<usize>,
    /// `head_map[l][h]` = KV head of the anchor layer that reuse layer `l`'s
    /// KV head `h` reads (identity rows for anchor layers).
    pub head_map: Vec<Vec<usize>>,
    pub topk: TopKRule,
    /// Provenance: similarity objective value of the selected anchor set.
    pub objective: f32,
}

impl KascadePlan {
    /// Build a plan from an anchor set with identity head maps (used by
    /// tests and by the all-heads-pooled variant where maps are moot).
    pub fn from_anchors(n_layers: usize, n_kv_heads: usize, mut anchors: Vec<usize>, topk: TopKRule) -> Self {
        anchors.sort_unstable();
        anchors.dedup();
        if anchors.first() != Some(&0) {
            anchors.insert(0, 0);
        }
        let segment_of = segment_map(n_layers, &anchors);
        let head_map = vec![(0..n_kv_heads).collect(); n_layers];
        Self { n_layers, n_kv_heads, anchors, segment_of, head_map, topk, objective: 0.0 }
    }

    pub fn role(&self, layer: usize) -> LayerRole {
        if self.anchors.binary_search(&layer).is_ok() {
            if layer == 0 {
                LayerRole::Anchor0
            } else {
                LayerRole::Anchor
            }
        } else {
            LayerRole::Reuse { anchor: self.segment_of[layer] }
        }
    }

    /// Fraction of layers that run (near-)full-cost attention — the quantity
    /// behind the paper's speedup-weighting (Table 3 caption).
    pub fn anchor_fraction(&self) -> f32 {
        self.anchors.len() as f32 / self.n_layers as f32
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("anchors", Json::usize_arr(&self.anchors)),
            ("segment_of", Json::usize_arr(&self.segment_of)),
            (
                "head_map",
                Json::arr(self.head_map.iter().map(|r| Json::usize_arr(r))),
            ),
            (
                "topk",
                Json::obj(vec![
                    ("frac", Json::num(self.topk.frac as f64)),
                    ("min_k", Json::num(self.topk.min_k as f64)),
                ]),
            ),
            ("objective", Json::num(self.objective as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let topk = j.req("topk")?;
        let plan = Self {
            n_layers: j.req("n_layers")?.as_usize().unwrap_or(0),
            n_kv_heads: j.req("n_kv_heads")?.as_usize().unwrap_or(0),
            anchors: j.req("anchors")?.usize_vec()?,
            segment_of: j.req("segment_of")?.usize_vec()?,
            head_map: j
                .req("head_map")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("head_map must be an array"))?
                .iter()
                .map(|r| r.usize_vec())
                .collect::<anyhow::Result<_>>()?,
            topk: TopKRule::new(
                topk.req("frac")?.as_f64().unwrap_or(0.1) as f32,
                topk.req("min_k")?.as_usize().unwrap_or(128),
            ),
            objective: j.get("objective").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
        };
        plan.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(plan)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.anchors.is_empty() || self.anchors[0] != 0 {
            return Err("anchor set must contain layer 0".into());
        }
        if self.segment_of.len() != self.n_layers || self.head_map.len() != self.n_layers {
            return Err("segment_of/head_map length mismatch".into());
        }
        for (l, &a) in self.segment_of.iter().enumerate() {
            if a > l || self.anchors.binary_search(&a).is_err() {
                return Err(format!("layer {l}: invalid segment anchor {a}"));
            }
        }
        for (l, hm) in self.head_map.iter().enumerate() {
            if hm.len() != self.n_kv_heads {
                return Err(format!("layer {l}: head map has {} entries", hm.len()));
            }
            if hm.iter().any(|&h| h >= self.n_kv_heads) {
                return Err(format!("layer {l}: head map index out of range"));
            }
        }
        Ok(())
    }
}

/// For each layer, the anchor whose segment contains it.
pub fn segment_map(n_layers: usize, anchors: &[usize]) -> Vec<usize> {
    let mut seg = vec![0; n_layers];
    let mut cur = anchors[0];
    let mut next_i = 1;
    for (l, s) in seg.iter_mut().enumerate() {
        if next_i < anchors.len() && anchors[next_i] == l {
            cur = l;
            next_i += 1;
        }
        *s = cur;
    }
    seg
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> KascadePlan {
        KascadePlan::from_anchors(16, 4, vec![0, 2, 8, 13, 14], TopKRule::default())
    }

    #[test]
    fn roles_match_paper_semantics() {
        let p = plan();
        assert_eq!(p.role(0), LayerRole::Anchor0);
        assert_eq!(p.role(2), LayerRole::Anchor);
        assert_eq!(p.role(1), LayerRole::Reuse { anchor: 0 });
        assert_eq!(p.role(7), LayerRole::Reuse { anchor: 2 });
        assert_eq!(p.role(15), LayerRole::Reuse { anchor: 14 });
    }

    #[test]
    fn layer_zero_forced_into_anchor_set() {
        let p = KascadePlan::from_anchors(8, 2, vec![3, 5], TopKRule::default());
        assert_eq!(p.anchors, vec![0, 3, 5]);
        p.validate().unwrap();
    }

    #[test]
    fn segment_map_is_previous_anchor() {
        let seg = segment_map(10, &[0, 4, 7]);
        assert_eq!(seg, vec![0, 0, 0, 0, 4, 4, 4, 7, 7, 7]);
    }

    #[test]
    fn anchor_fraction() {
        assert!((plan().anchor_fraction() - 5.0 / 16.0).abs() < 1e-6);
    }

    #[test]
    fn json_roundtrip() {
        let p = plan();
        let s = p.to_json().to_string();
        let q = KascadePlan::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.anchors, p.anchors);
        assert_eq!(q.segment_of, p.segment_of);
        assert_eq!(q.head_map, p.head_map);
        assert_eq!(q.topk.min_k, p.topk.min_k);
    }

    #[test]
    fn validate_rejects_forward_reference() {
        let mut p = plan();
        p.segment_of[1] = 8; // layer 1 cannot reuse a *later* anchor
        assert!(p.validate().is_err());
    }
}
