//! Head remapping (Sec. 3.5): map each reuse-layer KV head to the most
//! similar KV head of its anchor layer (many-to-one allowed).

use super::plan::segment_map;
use super::similarity::SimilarityBuilder;

/// `head_map[l][hb]` = anchor head whose Top-k indices reuse layer `l`'s
/// head `hb` should consume.  Anchor layers get identity rows.
pub fn build_head_maps(
    sim: &SimilarityBuilder,
    n_layers: usize,
    anchors: &[usize],
) -> Vec<Vec<usize>> {
    let seg = segment_map(n_layers, anchors);
    (0..n_layers)
        .map(|l| {
            let a = seg[l];
            if a == l {
                (0..sim.n_kv).collect()
            } else {
                (0..sim.n_kv)
                    .map(|hb| {
                        let mut best = 0;
                        let mut best_v = f32::NEG_INFINITY;
                        for ha in 0..sim.n_kv {
                            let v = sim.head_similarity(a, l, ha, hb);
                            if v > best_v {
                                best_v = v;
                                best = ha;
                            }
                        }
                        best
                    })
                    .collect()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kascade::similarity::{CalibrationCapture, ProbeCapture};

    /// Heads of layer 1 are a swap of layer 0's heads.
    fn swapped_capture() -> CalibrationCapture {
        let len = 64;
        let mk = |peak: usize| {
            let mut d = vec![1e-4f32; len];
            d[peak] = 1.0;
            let s: f32 = d.iter().sum();
            d.iter_mut().for_each(|x| *x /= s);
            d
        };
        let a = mk(5);
        let b = mk(40);
        CalibrationCapture {
            n_layers: 2,
            n_kv: 2,
            probes: vec![ProbeCapture {
                dists: vec![vec![a.clone(), b.clone()], vec![b, a]],
                importance: vec![1.0, 1.0],
            }],
        }
    }

    #[test]
    fn detects_swapped_heads() {
        let mut sim = SimilarityBuilder::new(2, 2, 8);
        sim.add_prompt(&swapped_capture());
        let maps = build_head_maps(&sim, 2, &[0]);
        assert_eq!(maps[0], vec![0, 1]); // anchor: identity
        assert_eq!(maps[1], vec![1, 0]); // reuse layer reads swapped heads
    }

    #[test]
    fn anchor_layers_are_identity() {
        let mut sim = SimilarityBuilder::new(2, 2, 8);
        sim.add_prompt(&swapped_capture());
        let maps = build_head_maps(&sim, 2, &[0, 1]);
        assert_eq!(maps[1], vec![0, 1]);
    }
}
