//! Minimal numeric substrate for the native attention engine.
//!
//! Deliberately small: flat `f32` buffers with explicit dimensions, plus
//! the handful of kernels the engine needs (dot products, blocked
//! mat-vec, softmax, RMSNorm, RoPE, partial top-k).  Hot loops are written
//! so rustc can auto-vectorize them (contiguous slices, no bounds checks
//! in the inner loop via `chunks_exact`).

/// Deterministic SplitMix64 PRNG — reproducible weight/workload generation
/// without external crates.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n) — exactly uniform, not modulo-biased.
    ///
    /// Rejection-samples the tiny top-of-range zone where `% n` would
    /// over-represent small residues (for non-power-of-two `n` the naive
    /// `next_u64() % n` skews by up to `n / 2^64` per value — invisible
    /// for tiny `n` but a real distribution defect for workload
    /// shuffles).  The reject zone has probability `< n / 2^64`, so for
    /// every practical `n` the first draw is accepted and the emitted
    /// sequence is unchanged from the biased version — existing seeded
    /// tests keep their data.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0)");
        let n64 = n as u64;
        // 2^64 mod n; accepted draws lie in [0, 2^64 - rem), a multiple of n
        let rem = (u64::MAX % n64 + 1) % n64;
        loop {
            let v = self.next_u64();
            if rem == 0 || v < u64::MAX - rem + 1 {
                return (v % n64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-9);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with i.i.d. N(0, scale^2).
    pub fn fill_normal(&mut self, buf: &mut [f32], scale: f32) {
        for x in buf.iter_mut() {
            *x = self.normal() * scale;
        }
    }

    /// Random unit vector of dimension `d` (appended to `out`).
    pub fn unit_vector(&mut self, d: usize) -> Vec<f32> {
        let mut v = vec![0.0; d];
        self.fill_normal(&mut v, 1.0);
        let n = norm(&v).max(1e-12);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled so LLVM emits vector FMAs.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (x, y) = (&a[i * 4..i * 4 + 4], &b[i * 4..i * 4 + 4]);
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

pub fn cosine_sim(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-12 || nb < 1e-12 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// y[n] = x[m] * w[m][n]  (w row-major [m, n]).
pub fn matvec_t(x: &[f32], w: &[f32], m: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi != 0.0 {
            axpy(y, xi, &w[i * n..(i + 1) * n]);
        }
    }
}

/// ys[b][n] = xs[b][m] * w[m][n]  (w row-major [m, n], xs row-major [b, m]).
///
/// Step-batched mat-mul for the decode engine: the loop is **weight-row
/// major** so each row of `w` is streamed exactly once and serves every
/// batch row while it is hot in cache — the memory-bandwidth win over
/// calling [`matvec_t`] per sequence.  Each output row accumulates its
/// `w`-row contributions in the same ascending-`i` order (with the same
/// zero-skip) as `matvec_t`, so per-row results are **bitwise identical**
/// to the sequential path.
pub fn matmul_t(xs: &[f32], w: &[f32], b: usize, m: usize, n: usize, ys: &mut [f32]) {
    debug_assert_eq!(xs.len(), b * m);
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(ys.len(), b * n);
    ys.fill(0.0);
    for i in 0..m {
        let wrow = &w[i * n..(i + 1) * n];
        for r in 0..b {
            let xi = xs[r * m + i];
            if xi != 0.0 {
                axpy(&mut ys[r * n..(r + 1) * n], xi, wrow);
            }
        }
    }
}

/// In-place numerically-stable softmax.  Returns the max score (useful for
/// diagnostics).  All-(-inf) rows become all-zero rather than NaN.
pub fn softmax(s: &mut [f32]) -> f32 {
    let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        s.fill(0.0);
        return m;
    }
    let mut z = 0.0;
    for x in s.iter_mut() {
        *x = (*x - m).exp();
        z += *x;
    }
    let inv = 1.0 / z;
    for x in s.iter_mut() {
        *x *= inv;
    }
    m
}

/// RMSNorm: x / rms(x) * w.
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// Rotary embedding applied in place to one head vector `x` (`d` even) at
/// absolute position `pos`.  Matches python/compile/model.py::rope
/// (half-split layout).
pub fn rope(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[half + i]);
        x[i] = a * cos - b * sin;
        x[half + i] = a * sin + b * cos;
    }
}

/// Indices of the `k` largest values (ties broken by lower index), in
/// descending value order.  O(n log k) via a bounded min-heap.
pub fn topk_indices(vals: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Entry(f32, u32); // min-heap on value (then max index out first)
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // reversed: smallest value at the top of the heap
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(vals.len());
    if k == 0 {
        return Vec::new();
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &v) in vals.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(v, i as u32));
        } else if let Some(top) = heap.peek() {
            if v > top.0 || (v == top.0 && (i as u32) < top.1) {
                heap.pop();
                heap.push(Entry(v, i as u32));
            }
        }
    }
    let mut out: Vec<(f32, u32)> = heap.into_iter().map(|e| (e.0, e.1)).collect();
    out.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    out.into_iter().map(|(_, i)| i).collect()
}

/// Indices of the `k` largest values in **unspecified order** — O(n)
/// expected via quickselect.  The attention engine's Top-k selection does
/// not need sorted output (softmax is order-invariant), which makes this
/// ~5-8x faster than the ordered heap variant at long contexts
/// (EXPERIMENTS.md §Perf).
pub fn topk_indices_unordered(vals: &[f32], k: usize) -> Vec<u32> {
    let mut pairs = Vec::new();
    let mut out = Vec::new();
    topk_unordered_into(vals, k, &mut pairs, &mut out);
    out
}

/// Allocation-free variant of [`topk_indices_unordered`]: partitions in
/// the caller's `pairs` staging buffer and APPENDS the selected indices
/// to `out` (both keep their capacity across calls — this is the Top-k
/// primitive behind the zero-allocation decode hot loop).  Selects the
/// exact same index set as the Vec-returning wrapper (same algorithm,
/// same deterministic pivot sequence).
pub fn topk_unordered_into(
    vals: &[f32],
    k: usize,
    pairs: &mut Vec<(f32, u32)>,
    out: &mut Vec<u32>,
) {
    let n = vals.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n as u32);
        return;
    }
    // Partition (value, index) pairs in place: sequential memory access in
    // the partition loop beats indirecting through an index array by ~2x
    // at long contexts (EXPERIMENTS.md §Perf iteration 2).
    pairs.clear();
    pairs.extend(vals.iter().copied().zip(0..n as u32));
    topk_prestaged(pairs, n, k, out);
}

/// Quickselect over an already-staged `pairs` buffer (the `(value, index)`
/// pairs for positions `0..n`, in position order) — the partition half of
/// [`topk_unordered_into`], split out so `simd::topk_into` can own the
/// staging fill while sharing this exact pivot sequence.  The swap chain
/// is data-dependent and stays scalar at every SIMD level; callers must
/// have handled the `k == 0` / `k == n` fast paths already.
pub fn topk_prestaged(pairs: &mut [(f32, u32)], n: usize, k: usize, out: &mut Vec<u32>) {
    debug_assert_eq!(pairs.len(), n);
    debug_assert!(k > 0 && k < n);
    let (mut lo, mut hi) = (0usize, n);
    let mut rng_state = 0x9E3779B97F4A7C15u64 ^ (n as u64);
    while hi - lo > 1 {
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        let p = lo + (rng_state as usize) % (hi - lo);
        let pivot = pairs[p].0;
        // partition: [lo, i) > pivot, [i, j) == pivot, [j, hi) < pivot
        let (mut i, mut j, mut m) = (lo, lo, hi);
        while j < m {
            let v = pairs[j].0;
            if v > pivot {
                pairs.swap(i, j);
                i += 1;
                j += 1;
            } else if v < pivot {
                m -= 1;
                pairs.swap(j, m);
            } else {
                j += 1;
            }
        }
        if k <= i {
            hi = i;
        } else if k >= j {
            lo = j;
        } else {
            break; // k falls inside the equal-to-pivot run
        }
    }
    out.extend(pairs[..k].iter().map(|&(_, i)| i));
}

// ---------------------------------------------------------------------------
// int8 quantization kernels (quantized paged-KV storage)
// ---------------------------------------------------------------------------

/// Affine int8 quantization of one tile: `x ~= scale * q + zero` with
/// `q` in `[-127, 127]`.  Returns `(scale, zero)`.
///
/// `scale`/`zero` are chosen from the tile's **finite** min/max, so the
/// round-trip error of every finite element is bounded by
/// `scale / 2 = (max - min) / 508`.  A constant tile gets
/// `scale == 0.0` and all-zero codes (dequantizing to exactly `zero`);
/// non-finite elements saturate to the code range (NaN encodes as 0,
/// i.e. dequantizes to the tile midpoint) without poisoning the scale
/// of their healthy neighbors.
pub fn quantize_q8(src: &[f32], dst: &mut [i8]) -> (f32, f32) {
    debug_assert_eq!(src.len(), dst.len());
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in src {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        // empty tile or no finite elements: store zeros
        dst.fill(0);
        return (0.0, 0.0);
    }
    let zero = 0.5 * (lo + hi);
    let scale = (hi - lo) / 254.0;
    if scale <= 0.0 {
        dst.fill(0);
        return (0.0, zero);
    }
    let inv = 1.0 / scale;
    for (d, &x) in dst.iter_mut().zip(src.iter()) {
        let q = ((x - zero) * inv).round();
        *d = q.clamp(-127.0, 127.0) as i8;
    }
    (scale, zero)
}

/// Dequantize `q` with an affine `(scale, zero)` pair into `out`.
pub fn dequantize_q8(q: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for (o, &c) in out.iter_mut().zip(q.iter()) {
        *o = c as f32 * scale + zero;
    }
}

/// Affine int4 quantization of one tile: `x ~= scale * q + zero` with
/// `q` in `[-7, 7]`, two codes packed per byte (low nibble = even
/// element, biased by +8 so a nibble is always in `[1, 15]`, with 8
/// encoding `q = 0`).  Returns `(scale, zero)`.
///
/// This is the warm-tier codec of the tiered KV hierarchy
/// (`docs/kv-tiers.md`): a compressed RAM shadow of a demoted tile,
/// never the source of truth.  Edge conventions mirror [`quantize_q8`]:
/// `scale`/`zero` come from the tile's finite min/max so every finite
/// element round-trips within `scale / 2 = (max - min) / 28`; a
/// constant tile gets `scale == 0.0` and all-mid codes; non-finite
/// elements saturate (NaN encodes as the tile midpoint) without
/// poisoning their neighbors' scale.
pub fn quantize_q4(src: &[f32], dst: &mut [u8]) -> (f32, f32) {
    debug_assert_eq!(src.len() % 2, 0, "int4 packing needs an even element count");
    debug_assert_eq!(src.len() / 2, dst.len());
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in src {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        // empty tile or no finite elements: both nibbles encode q = 0
        dst.fill(0x88);
        return (0.0, 0.0);
    }
    let zero = 0.5 * (lo + hi);
    let scale = (hi - lo) / 14.0;
    if scale <= 0.0 {
        dst.fill(0x88);
        return (0.0, zero);
    }
    let inv = 1.0 / scale;
    let code = |x: f32| -> u8 {
        // NaN: `NaN as i32 == 0`, i.e. the tile midpoint, like quantize_q8
        let q = ((x - zero) * inv).round().clamp(-7.0, 7.0) as i32;
        (q + 8) as u8
    };
    for (i, d) in dst.iter_mut().enumerate() {
        *d = code(src[2 * i]) | (code(src[2 * i + 1]) << 4);
    }
    (scale, zero)
}

/// Dequantize packed int4 codes ([`quantize_q4`] layout) with an affine
/// `(scale, zero)` pair into `out` (`out.len() == 2 * q.len()`).
pub fn dequantize_q4(q: &[u8], scale: f32, zero: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len() * 2, out.len());
    for (i, &b) in q.iter().enumerate() {
        let q0 = (b & 0x0F) as i32 - 8;
        let q1 = (b >> 4) as i32 - 8;
        out[2 * i] = q0 as f32 * scale + zero;
        out[2 * i + 1] = q1 as f32 * scale + zero;
    }
}

/// 4-lane unrolled element sum, accumulation order identical to the `da`
/// accumulator inside [`qk_dot_q8`] — the tile-major kernels hoist this
/// per-query sum out of the per-row loop (the int8 zero-point term is
/// `zero * sum(q)`, constant across a tile) and stay bitwise-equal to
/// the fused row-at-a-time path.
#[inline]
pub fn sum4(a: &[f32]) -> f32 {
    let mut sa = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let x = &a[i * 4..i * 4 + 4];
        sa[0] += x[0];
        sa[1] += x[1];
        sa[2] += x[2];
        sa[3] += x[3];
    }
    let mut da = sa[0] + sa[1] + sa[2] + sa[3];
    for &x in &a[chunks * 4..] {
        da += x;
    }
    da
}

/// f32 x int8 raw dot (`sum a_i * q_i`), accumulation order identical to
/// the `dq` accumulator inside [`qk_dot_q8`].  Combined with [`sum4`]:
/// `scale * dot_i8(a, q) + zero * sum4(a)` is bitwise-equal to
/// `qk_dot_q8(a, q, scale, zero)`.
#[inline]
pub fn dot_i8(a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let mut sq = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 4..i * 4 + 4]);
        sq[0] += x[0] * c[0] as f32;
        sq[1] += x[1] * c[1] as f32;
        sq[2] += x[2] * c[2] as f32;
        sq[3] += x[3] * c[3] as f32;
    }
    let mut dq = sq[0] + sq[1] + sq[2] + sq[3];
    for i in chunks * 4..a.len() {
        dq += a[i] * q[i] as f32;
    }
    dq
}

/// Fused f32 x int8 dot product: `dot(a, scale * q + zero)` without
/// materializing the dequantized row.  One pass accumulates both
/// `sum a_i * q_i` and `sum a_i`, so the zero-point costs no extra
/// memory traffic — this is the scoring kernel for quantized KV tiles.
#[inline]
pub fn qk_dot_q8(a: &[f32], q: &[i8], scale: f32, zero: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let mut sq = [0.0f32; 4];
    let mut sa = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 4..i * 4 + 4]);
        sq[0] += x[0] * c[0] as f32;
        sq[1] += x[1] * c[1] as f32;
        sq[2] += x[2] * c[2] as f32;
        sq[3] += x[3] * c[3] as f32;
        sa[0] += x[0];
        sa[1] += x[1];
        sa[2] += x[2];
        sa[3] += x[3];
    }
    let mut dq = sq[0] + sq[1] + sq[2] + sq[3];
    let mut da = sa[0] + sa[1] + sa[2] + sa[3];
    for i in chunks * 4..a.len() {
        dq += a[i] * q[i] as f32;
        da += a[i];
    }
    scale * dq + zero * da
}

/// Fused `y += w * (scale * q + zero)` — the weighted-value
/// accumulation over a quantized V row (dequantize-on-attend).
#[inline]
pub fn axpy_q8(y: &mut [f32], w: f32, q: &[i8], scale: f32, zero: f32) {
    debug_assert_eq!(y.len(), q.len());
    let ws = w * scale;
    let wz = w * zero;
    for (yi, &c) in y.iter_mut().zip(q.iter()) {
        *yi += ws * c as f32 + wz;
    }
}

// ---------------------------------------------------------------------------
// f16 (IEEE 754 binary16) software conversion + kernels
// ---------------------------------------------------------------------------

/// Convert an f32 to IEEE 754 binary16 bits with round-to-nearest-even,
/// handling subnormals, overflow-to-infinity, and NaN payload
/// preservation (top 10 payload bits, quiet bit forced).  Software
/// conversion keeps the `KvDtype::F16` storage mode byte-identical
/// across hosts with and without hardware F16C/FP16 units.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf / NaN: keep the top payload bits, force the quiet bit so a
        // signaling-NaN payload that truncates to zero stays a NaN.
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7C00 | 0x0200 | ((man >> 13) as u16 & 0x03FF)
        };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> +/-inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> +/-0
        }
        // Subnormal half: re-attach the implicit bit, shift into place,
        // round-to-nearest-even on the dropped bits.  A mantissa carry
        // into 0x0400 lands exactly on the smallest normal — correct.
        let man = man | 0x80_0000;
        let shift = (14 - e) as u32; // 14..=24
        let kept = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let rounded = if rem > half || (rem == half && kept & 1 != 0) {
            kept + 1
        } else {
            kept
        };
        return sign | rounded as u16;
    }
    // Normal half: keep the top 10 mantissa bits, round-to-nearest-even.
    // A mantissa carry may overflow into the exponent (up to infinity at
    // e == 30) — that is the correctly rounded result.
    let kept = man >> 13;
    let rem = man & 0x1FFF;
    let mut h = (sign as u32) | ((e as u32) << 10) | kept;
    if rem > 0x1000 || (rem == 0x1000 && kept & 1 != 0) {
        h += 1;
    }
    h as u16
}

/// Convert IEEE 754 binary16 bits to f32 — exact (every f16 value is
/// representable in f32, so this direction never rounds).  Hardware
/// converters (F16C `vcvtph2ps`, NEON `fcvtl`) compute the identical
/// bit pattern, which is what lets the SIMD f16 kernels stay bitwise
/// equal to this software path.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = (h as u32 & 0x8000) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal half = m * 2^-24: normalize into f32 form.  With
            // the leading 1 of `m` at bit p (= 31 - leading_zeros), the
            // value is 2^(p-24) * (1.frac), so the f32 exponent field is
            // p - 24 + 127 = 134 - leading_zeros and the mantissa shifts
            // up by 23 - p = leading_zeros - 8.
            let lz = m.leading_zeros();
            sign | ((134 - lz) << 23) | ((m << (lz - 8)) & 0x7F_FFFF)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e as u32 + 112) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// f32 x f16 dot product with f32 accumulation: each stored half is
/// converted (exactly) to f32 and accumulated in the same 4-lane
/// structure as [`dot`] — the scoring kernel for `KvDtype::F16` tiles.
#[inline]
pub fn dot_f16(a: &[f32], h: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), h.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (x, c) = (&a[i * 4..i * 4 + 4], &h[i * 4..i * 4 + 4]);
        acc[0] += x[0] * f16_to_f32(c[0]);
        acc[1] += x[1] * f16_to_f32(c[1]);
        acc[2] += x[2] * f16_to_f32(c[2]);
        acc[3] += x[3] * f16_to_f32(c[3]);
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * f16_to_f32(h[i]);
    }
    s
}

/// `y += w * h` over an f16 V row (convert-on-attend, f32 accumulation)
/// — the value-accumulation kernel for `KvDtype::F16` tiles.
#[inline]
pub fn axpy_f16(y: &mut [f32], w: f32, h: &[u16]) {
    debug_assert_eq!(y.len(), h.len());
    for (yi, &c) in y.iter_mut().zip(h.iter()) {
        *yi += w * f16_to_f32(c);
    }
}

// ---------------------------------------------------------------------------
// packed-int4 fused kernels (first-class KvDtype::Int4 storage mode)
// ---------------------------------------------------------------------------

/// f32 x packed-int4 raw dot (`sum a_i * q_i` over unpacked codes, two
/// per byte in [`quantize_q4`] layout), accumulation order identical to
/// the `dq` accumulator inside [`qk_dot_q4`].  Combined with [`sum4`]:
/// `scale * dot_i4(a, q) + zero * sum4(a)` is bitwise-equal to
/// `qk_dot_q4(a, q, scale, zero)`.
#[inline]
pub fn dot_i4(a: &[f32], q: &[u8]) -> f32 {
    debug_assert_eq!(a.len(), q.len() * 2);
    let mut sq = [0.0f32; 4];
    let chunks = q.len() / 2;
    for i in 0..chunks {
        let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 2..i * 2 + 2]);
        sq[0] += x[0] * ((c[0] & 0x0F) as i32 - 8) as f32;
        sq[1] += x[1] * ((c[0] >> 4) as i32 - 8) as f32;
        sq[2] += x[2] * ((c[1] & 0x0F) as i32 - 8) as f32;
        sq[3] += x[3] * ((c[1] >> 4) as i32 - 8) as f32;
    }
    let mut dq = sq[0] + sq[1] + sq[2] + sq[3];
    for i in chunks * 2..q.len() {
        let b = q[i];
        dq += a[2 * i] * ((b & 0x0F) as i32 - 8) as f32;
        dq += a[2 * i + 1] * ((b >> 4) as i32 - 8) as f32;
    }
    dq
}

/// Fused f32 x packed-int4 dot product: `dot(a, scale * q + zero)`
/// without materializing the dequantized row — the Top-k scoring kernel
/// for `KvDtype::Int4` tiles, mirroring [`qk_dot_q8`]'s one-pass
/// dual-accumulator shape over nibble codes.
#[inline]
pub fn qk_dot_q4(a: &[f32], q: &[u8], scale: f32, zero: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len() * 2);
    let mut sq = [0.0f32; 4];
    let mut sa = [0.0f32; 4];
    let chunks = q.len() / 2;
    for i in 0..chunks {
        let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 2..i * 2 + 2]);
        sq[0] += x[0] * ((c[0] & 0x0F) as i32 - 8) as f32;
        sq[1] += x[1] * ((c[0] >> 4) as i32 - 8) as f32;
        sq[2] += x[2] * ((c[1] & 0x0F) as i32 - 8) as f32;
        sq[3] += x[3] * ((c[1] >> 4) as i32 - 8) as f32;
        sa[0] += x[0];
        sa[1] += x[1];
        sa[2] += x[2];
        sa[3] += x[3];
    }
    let mut dq = sq[0] + sq[1] + sq[2] + sq[3];
    let mut da = sa[0] + sa[1] + sa[2] + sa[3];
    for i in chunks * 2..q.len() {
        let b = q[i];
        dq += a[2 * i] * ((b & 0x0F) as i32 - 8) as f32;
        dq += a[2 * i + 1] * ((b >> 4) as i32 - 8) as f32;
        da += a[2 * i];
        da += a[2 * i + 1];
    }
    scale * dq + zero * da
}

/// Fused `y += w * (scale * q + zero)` over a packed-int4 V row
/// (dequantize-on-attend), mirroring [`axpy_q8`].
#[inline]
pub fn axpy_q4(y: &mut [f32], w: f32, q: &[u8], scale: f32, zero: f32) {
    debug_assert_eq!(y.len(), q.len() * 2);
    let ws = w * scale;
    let wz = w * zero;
    for (i, &b) in q.iter().enumerate() {
        y[2 * i] += ws * ((b & 0x0F) as i32 - 8) as f32 + wz;
        y[2 * i + 1] += ws * ((b >> 4) as i32 - 8) as f32 + wz;
    }
}

/// Splitmix64 finalizer — the shared integer mixer behind the router's
/// session hash and the counter-based sampling RNG.  Keep the constants
/// here, in one place.
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// argmax of a slice (first max wins).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(99);
        for n in [1usize, 2, 3, 7, 10, 255, 1000] {
            let draws = 6000;
            let mut counts = vec![0u32; n.min(16)];
            for _ in 0..draws {
                let v = r.below(n);
                assert!(v < n, "below({n}) returned {v}");
                if n <= 16 {
                    counts[v] += 1;
                }
            }
            if n <= 16 && n > 1 {
                let expect = draws as f64 / n as f64;
                for (v, &c) in counts.iter().enumerate() {
                    let dev = (c as f64 - expect).abs() / expect;
                    assert!(dev < 0.25, "below({n}) bucket {v}: {c} vs {expect:.0}");
                }
            }
        }
    }

    /// The rejection zone is < n / 2^64 of the draw space, so for small n
    /// the emitted sequence matches the historical `% n` mapping — seeded
    /// test data across the repo is unchanged by the bias fix.
    #[test]
    fn below_sequence_stable_for_small_n() {
        for seed in [0u64, 42, 0xDEAD] {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            for _ in 0..512 {
                let n = 1 + (b.0 as usize % 63).min(62); // arbitrary small n per step
                let want = {
                    let mut c = a.clone();
                    (c.next_u64() % n as u64) as usize
                };
                assert_eq!(a.below(n), want);
                b.next_u64();
            }
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0, -1.0];
        softmax(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0] && s[0] > s[3]);
    }

    #[test]
    fn softmax_handles_neg_inf_rows() {
        let mut s = vec![f32::NEG_INFINITY; 4];
        softmax(&mut s);
        assert!(s.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn softmax_extreme_scores_stable() {
        let mut s = vec![120.0, 0.0, -120.0];
        softmax(&mut s);
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let w = vec![1.0; 64];
        let mut y = vec![0.0; 64];
        rmsnorm(&x, &w, &mut y);
        let rms = (dot(&y, &y) / 64.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-2);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let orig: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
        let mut x = orig.clone();
        rope(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
        rope(&mut x, 1234, 10000.0);
        assert!((norm(&x) - norm(&orig)).abs() < 1e-3);
    }

    #[test]
    fn rope_relative_invariance() {
        // <rope(q,p1), rope(k,p2)> depends only on p1 - p2
        let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let k: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).cos()).collect();
        let score = |p1: usize, p2: usize| {
            let mut a = q.clone();
            let mut b = k.clone();
            rope(&mut a, p1, 10000.0);
            rope(&mut b, p2, 10000.0);
            dot(&a, &b)
        };
        assert!((score(10, 3) - score(110, 103)).abs() < 1e-3);
    }

    #[test]
    fn topk_basic() {
        let v = vec![0.1, 0.9, 0.3, 0.7, 0.5];
        assert_eq!(topk_indices(&v, 2), vec![1, 3]);
        assert_eq!(topk_indices(&v, 5), vec![1, 3, 4, 2, 0]);
        assert_eq!(topk_indices(&v, 9).len(), 5);
        assert!(topk_indices(&v, 0).is_empty());
    }

    #[test]
    fn topk_matches_sort_on_random_input() {
        let mut r = Rng::new(11);
        for _ in 0..20 {
            let n = 50 + r.below(200);
            let k = 1 + r.below(n);
            let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let got = topk_indices(&vals, k);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            idx.sort_by(|&a, &b| {
                vals[b as usize]
                    .partial_cmp(&vals[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            assert_eq!(got, idx[..k].to_vec());
        }
    }

    #[test]
    fn matmul_t_rows_bitwise_equal_matvec_t() {
        let mut r = Rng::new(17);
        let (m, n) = (48, 33);
        for b in [1usize, 2, 5, 8] {
            let mut xs = vec![0.0; b * m];
            let mut w = vec![0.0; m * n];
            r.fill_normal(&mut xs, 1.0);
            r.fill_normal(&mut w, 1.0);
            // sprinkle exact zeros so the zero-skip path is exercised
            for i in (0..xs.len()).step_by(7) {
                xs[i] = 0.0;
            }
            let mut ys = vec![0.0; b * n];
            matmul_t(&xs, &w, b, m, n, &mut ys);
            for row in 0..b {
                let mut want = vec![0.0; n];
                matvec_t(&xs[row * m..(row + 1) * m], &w, m, n, &mut want);
                for (a, e) in ys[row * n..(row + 1) * n].iter().zip(&want) {
                    assert_eq!(a.to_bits(), e.to_bits(), "b={b} row={row}");
                }
            }
        }
    }

    #[test]
    fn matvec_t_matches_naive() {
        let mut r = Rng::new(5);
        let (m, n) = (13, 9);
        let mut x = vec![0.0; m];
        let mut w = vec![0.0; m * n];
        r.fill_normal(&mut x, 1.0);
        r.fill_normal(&mut w, 1.0);
        let mut y = vec![0.0; n];
        matvec_t(&x, &w, m, n, &mut y);
        for j in 0..n {
            let want: f32 = (0..m).map(|i| x[i] * w[i * n + j]).sum();
            assert!((y[j] - want).abs() < 1e-4);
        }
    }
}
#[cfg(test)]
mod quant_tests {
    use super::*;

    #[test]
    fn quantize_round_trip_error_bounded() {
        let mut r = Rng::new(31);
        for _ in 0..50 {
            let n = 1 + r.below(256);
            let scale_in = 0.1 + r.uniform() * 10.0;
            let src: Vec<f32> = (0..n).map(|_| r.normal() * scale_in).collect();
            let mut q = vec![0i8; n];
            let (s, z) = quantize_q8(&src, &mut q);
            let mut back = vec![0.0f32; n];
            dequantize_q8(&q, s, z, &mut back);
            let lo = src.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = src.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let bound = (hi - lo) / 508.0 + 1e-6;
            for (a, b) in src.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn quantize_constant_tile_is_exact() {
        let src = vec![3.25f32; 64];
        let mut q = vec![0i8; 64];
        let (s, z) = quantize_q8(&src, &mut q);
        assert_eq!(s, 0.0);
        assert!(q.iter().all(|&c| c == 0));
        let mut back = vec![0.0f32; 64];
        dequantize_q8(&q, s, z, &mut back);
        assert!(back.iter().all(|&x| x == 3.25));
    }

    #[test]
    fn qk_dot_q8_matches_dequantized_dot() {
        let mut r = Rng::new(33);
        for _ in 0..30 {
            let n = 1 + r.below(128);
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 0.5).collect();
            let mut q = vec![0i8; n];
            let (s, z) = quantize_q8(&src, &mut q);
            let mut deq = vec![0.0f32; n];
            dequantize_q8(&q, s, z, &mut deq);
            let want = dot(&a, &deq);
            let got = qk_dot_q8(&a, &q, s, z);
            assert!((want - got).abs() < 1e-3 * (1.0 + want.abs()), "{want} vs {got}");
        }
    }

    /// The tile-major kernels recompose `qk_dot_q8` as
    /// `scale * dot_i8 + zero * sum4` (zero-point term hoisted per tile);
    /// the split must be bitwise-equal to the fused kernel.
    #[test]
    fn split_dot_i8_sum4_bitwise_equals_qk_dot_q8() {
        let mut r = Rng::new(35);
        for _ in 0..40 {
            let n = 1 + r.below(130);
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 0.7).collect();
            let mut q = vec![0i8; n];
            let (s, z) = quantize_q8(&src, &mut q);
            let fused = qk_dot_q8(&a, &q, s, z);
            let split = s * dot_i8(&a, &q) + z * sum4(&a);
            assert_eq!(fused.to_bits(), split.to_bits(), "n={n}");
        }
    }

    #[test]
    fn quantize_q4_round_trip_error_bounded() {
        let mut r = Rng::new(41);
        for _ in 0..50 {
            let n = 2 * (1 + r.below(128));
            let scale_in = 0.1 + r.uniform() * 10.0;
            let src: Vec<f32> = (0..n).map(|_| r.normal() * scale_in).collect();
            let mut q = vec![0u8; n / 2];
            let (s, z) = quantize_q4(&src, &mut q);
            let mut back = vec![0.0f32; n];
            dequantize_q4(&q, s, z, &mut back);
            let lo = src.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = src.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let bound = (hi - lo) / 28.0 + 1e-6;
            for (a, b) in src.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn quantize_q4_packing_order_and_edges() {
        // low nibble = even element
        let src = vec![-1.0f32, 1.0];
        let mut q = vec![0u8; 1];
        let (s, z) = quantize_q4(&src, &mut q);
        assert_eq!(z, 0.0);
        assert_eq!(q[0] & 0x0F, (8 - 7) as u8, "min maps to q = -7");
        assert_eq!(q[0] >> 4, (8 + 7) as u8, "max maps to q = +7");
        let mut back = vec![0.0f32; 2];
        dequantize_q4(&q, s, z, &mut back);
        assert!((back[0] + 1.0).abs() < 1e-6 && (back[1] - 1.0).abs() < 1e-6);
        // constant tile: scale 0, exact round trip through `zero`
        let src = vec![2.5f32; 32];
        let mut q = vec![0u8; 16];
        let (s, z) = quantize_q4(&src, &mut q);
        assert_eq!(s, 0.0);
        assert!(q.iter().all(|&b| b == 0x88));
        let mut back = vec![0.0f32; 32];
        dequantize_q4(&q, s, z, &mut back);
        assert!(back.iter().all(|&x| x == 2.5));
        // NaN encodes as the tile midpoint without poisoning the scale
        let src = vec![0.0f32, f32::NAN, 4.0, 2.0];
        let mut q = vec![0u8; 2];
        let (s, z) = quantize_q4(&src, &mut q);
        let mut back = vec![0.0f32; 4];
        dequantize_q4(&q, s, z, &mut back);
        assert!((back[1] - 2.0).abs() < 1e-6, "NaN -> midpoint, got {}", back[1]);
        assert!((back[2] - 4.0).abs() <= s * 0.5 + 1e-6);
    }

    /// Tolerance gate of the warm tier against the hot int8 path: on the
    /// same tile, the int4 shadow must stay within the summed half-step
    /// bounds of the int8 codes it was built from.
    #[test]
    fn q4_shadow_within_tolerance_of_q8_path() {
        let mut r = Rng::new(43);
        for _ in 0..30 {
            let n = 2 * (1 + r.below(128));
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 3.0).collect();
            let mut q8c = vec![0i8; n];
            let (s8, z8) = quantize_q8(&src, &mut q8c);
            let mut hot = vec![0.0f32; n];
            dequantize_q8(&q8c, s8, z8, &mut hot);
            // warm shadow is built FROM the hot-tier payload, as in KvCache
            let mut q4c = vec![0u8; n / 2];
            let (s4, z4) = quantize_q4(&hot, &mut q4c);
            let mut warm = vec![0.0f32; n];
            dequantize_q4(&q4c, s4, z4, &mut warm);
            let bound = 0.5 * s4 + 1e-6;
            for (a, b) in hot.iter().zip(&warm) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn axpy_q8_matches_dequantized_axpy() {
        let mut r = Rng::new(34);
        let n = 96;
        let src: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mut q = vec![0i8; n];
        let (s, z) = quantize_q8(&src, &mut q);
        let mut deq = vec![0.0f32; n];
        dequantize_q8(&q, s, z, &mut deq);
        let mut want = vec![0.5f32; n];
        let mut got = vec![0.5f32; n];
        axpy(&mut want, 0.7, &deq);
        axpy_q8(&mut got, 0.7, &q, s, z);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    /// Exhaustive f16 round trip: every non-NaN half value survives
    /// f16 -> f32 -> f16 bit-exactly (f16 -> f32 is exact, and the
    /// nearest half to an exact half is itself).
    #[test]
    fn f16_round_trip_exhaustive() {
        // Miri interprets ~10^4x slower; a coprime stride still samples
        // every exponent/rounding class while keeping the run bounded.
        let stride: u32 = if cfg!(miri) { 251 } else { 1 };
        for h in (0u32..=u16::MAX as u32).step_by(stride as usize) {
            let h = h as u16;
            let exp = (h >> 10) & 0x1F;
            let man = h & 0x03FF;
            if exp == 0x1F && man != 0 {
                // NaN: payload may be quieted, but NaN-ness must survive
                assert!(f16_to_f32(h).is_nan());
                assert_eq!(f32_to_f16(f16_to_f32(h)) & 0x7C00, 0x7C00);
                continue;
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "h={h:#06x}");
        }
    }

    #[test]
    fn f32_to_f16_rounding_and_edges() {
        // exact values pass through
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF, "largest finite half");
        // overflow saturates to infinity (65520 rounds up past 65504)
        assert_eq!(f32_to_f16(65520.0), 0x7C00);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(-1e9), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // smallest subnormal half = 2^-24; half of it rounds to even (0)
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000, "ties to even");
        assert_eq!(f32_to_f16(2.0f32.powi(-25) * 1.5), 0x0001);
        // round-to-nearest-even at the normal boundary: 1 + 2^-11 is
        // exactly between 1.0 and the next half (1 + 2^-10) -> even (1.0)
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), 0x3C00);
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3C02);
        // relative error bound for normal-range values: 2^-11
        let mut r = Rng::new(51);
        for _ in 0..2000 {
            let x = (r.normal() * 8.0).clamp(-60000.0, 60000.0);
            if x.abs() < 6.2e-5 {
                continue; // below the normal-half range
            }
            let back = f16_to_f32(f32_to_f16(x));
            assert!(
                (back - x).abs() <= x.abs() * 2.0f32.powi(-11),
                "{x} -> {back}"
            );
        }
    }

    #[test]
    fn dot_f16_and_axpy_f16_match_converted_f32_kernels() {
        let mut r = Rng::new(53);
        for _ in 0..40 {
            let n = 1 + r.below(130);
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 2.0).collect();
            let h: Vec<u16> = src.iter().map(|&x| f32_to_f16(x)).collect();
            let deq: Vec<f32> = h.iter().map(|&c| f16_to_f32(c)).collect();
            // same accumulation structure as `dot` over the converted row
            assert_eq!(
                dot_f16(&a, &h).to_bits(),
                dot(&a, &deq).to_bits(),
                "n={n}"
            );
            let mut want = vec![0.25f32; n];
            let mut got = vec![0.25f32; n];
            axpy(&mut want, 0.7, &deq);
            axpy_f16(&mut got, 0.7, &h);
            for (x, y) in want.iter().zip(&got) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn split_dot_i4_sum4_bitwise_equals_qk_dot_q4() {
        let mut r = Rng::new(55);
        for _ in 0..40 {
            let n = 2 * (1 + r.below(70));
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 0.7).collect();
            let mut q = vec![0u8; n / 2];
            let (s, z) = quantize_q4(&src, &mut q);
            let fused = qk_dot_q4(&a, &q, s, z);
            let split = s * dot_i4(&a, &q) + z * sum4(&a);
            assert_eq!(fused.to_bits(), split.to_bits(), "n={n}");
        }
    }

    #[test]
    fn qk_dot_q4_matches_dequantized_dot() {
        let mut r = Rng::new(57);
        for _ in 0..40 {
            let n = 2 * (1 + r.below(70));
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 1.3).collect();
            let mut q = vec![0u8; n / 2];
            let (s, z) = quantize_q4(&src, &mut q);
            let mut deq = vec![0.0f32; n];
            dequantize_q4(&q, s, z, &mut deq);
            let want = dot(&a, &deq);
            let got = qk_dot_q4(&a, &q, s, z);
            let tol = 1e-4 * (1.0 + want.abs() + a.len() as f32 * s.abs());
            assert!((want - got).abs() <= tol, "{want} vs {got}");
        }
    }

    #[test]
    fn axpy_q4_matches_dequantized_axpy() {
        let mut r = Rng::new(59);
        let n = 96;
        let src: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mut q = vec![0u8; n / 2];
        let (s, z) = quantize_q4(&src, &mut q);
        let mut deq = vec![0.0f32; n];
        dequantize_q4(&q, s, z, &mut deq);
        let mut want = vec![0.5f32; n];
        let mut got = vec![0.5f32; n];
        axpy(&mut want, 0.7, &deq);
        axpy_q4(&mut got, 0.7, &q, s, z);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

#[cfg(test)]
mod quickselect_tests {
    use super::*;

    #[test]
    fn unordered_matches_ordered_as_sets() {
        let mut r = Rng::new(21);
        for _ in 0..50 {
            let n = 10 + r.below(3000);
            let k = 1 + r.below(n);
            let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let mut a = topk_indices(&vals, k);
            let mut b = topk_indices_unordered(&vals, k);
            a.sort_unstable();
            b.sort_unstable();
            // ties can legitimately differ in which duplicate index is
            // kept; compare the selected VALUES instead
            let va: Vec<f32> = a.iter().map(|&i| vals[i as usize]).collect();
            let mut vb: Vec<f32> = b.iter().map(|&i| vals[i as usize]).collect();
            let mut va2 = va.clone();
            va2.sort_by(|x, y| x.partial_cmp(y).unwrap());
            vb.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(va2, vb, "n={n} k={k}");
        }
    }

    #[test]
    fn into_variant_matches_wrapper_and_reuses_buffers() {
        let mut r = Rng::new(23);
        let mut pairs = Vec::new();
        let mut out = Vec::new();
        for _ in 0..30 {
            let n = 5 + r.below(500);
            let k = 1 + r.below(n);
            let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            out.clear();
            topk_unordered_into(&vals, k, &mut pairs, &mut out);
            assert_eq!(out, topk_indices_unordered(&vals, k), "n={n} k={k}");
        }
    }

    #[test]
    fn unordered_edge_cases() {
        assert!(topk_indices_unordered(&[], 3).is_empty());
        assert_eq!(topk_indices_unordered(&[1.0, 2.0], 5).len(), 2);
        let ties = vec![1.0f32; 100];
        assert_eq!(topk_indices_unordered(&ties, 40).len(), 40);
    }

    #[test]
    fn unordered_with_many_duplicates() {
        let mut r = Rng::new(5);
        let vals: Vec<f32> = (0..2000).map(|_| (r.below(8) as f32) * 0.125).collect();
        for k in [1, 7, 100, 1999] {
            let got = topk_indices_unordered(&vals, k);
            assert_eq!(got.len(), k);
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let thresh = sorted[k - 1];
            assert!(got.iter().all(|&i| vals[i as usize] >= thresh));
        }
    }
}
