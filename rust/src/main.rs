//! `kascade` CLI — leader entrypoint.
//!
//! Subcommands:
//!   eval <fig1..fig7|table1..table3|all> [--fast]   regenerate experiments
//!   calibrate [--anchors M] [--out plan.json]       offline anchor selection
//!   serve [--requests N] [--policy P]               run the serving demo
//!                                                   (streaming sessions; --deadline-ms bounds each request)
//!   traffic [--seed S] [--ticks N] [--rate R]       replay a seeded bursty multi-tenant
//!                                                   traffic stream through the engine and
//!                                                   report the TTFT/TPOT percentile surface
//!   gateway [--replicas N] [--workers N] [--port P]  HTTP front end over a replica registry
//!                                                   with prefix-affinity routing; --smoke
//!                                                   runs one bounded loopback generation +
//!                                                   drain cycle and exits (docs/gateway.md)
//!   export-weights [--out artifacts/synth_weights]  SynthLM -> PJRT weights
//!   pjrt-smoke                                      artifact load + parity check
//!
//! (clap is unavailable offline; this is a small hand-rolled parser.)

use kascade::config::ServeConfig;
use kascade::coordinator::{NativeBackend, Request};
use kascade::eval::{self, EvalOptions};
use kascade::kascade::{calibrate, CalibrateOptions};
use kascade::model::SynthSpec;
use kascade::server::{BackendFactory, Engine};
use kascade::sparse::{DensePolicy, KascadePolicy};
use kascade::workload::WorkloadGen;
use std::path::PathBuf;
use std::sync::Arc;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let has_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if has_value {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: kascade <command>\n\
         commands:\n\
           eval <fig1..fig7|table1|table2|table3|all> [--fast] [--out DIR]\n\
           calibrate [--anchors M] [--ctx N] [--prompts N] [--out plan.json]\n\
           serve [--requests N] [--policy dense|kascade] [--ctx N] [--workers N] [--threads N] [--deadline-ms MS]\n\
                 [--kv-dtype f32|f16|int8|int4] [--kv-tiers] [--hot-tile-budget N] [--spill PATH]\n\
           traffic [--seed S] [--ticks N] [--rate R] [--burst-rate R] [--prompt-cap N]\n\
                   [--guard TOKENS] [--fair-share] [--threads N]\n\
           gateway [--replicas N] [--workers N] [--port P] [--no-affinity]\n\
                   [--smoke] [--smoke-timeout-s S]\n\
           export-weights [--out PATH] [--seed S]\n\
           pjrt-smoke [--artifacts DIR]"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    match args.positional.first().map(|s| s.as_str()) {
        Some("eval") => {
            let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let opts = EvalOptions {
                fast: args.has("fast"),
                out_dir: PathBuf::from(args.flag("out").unwrap_or("results")),
                seed: args.flag("seed").and_then(|s| s.parse().ok()).unwrap_or(42),
            };
            eval::run(name, &opts)
        }
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve") => cmd_serve(&args),
        Some("traffic") => cmd_traffic(&args),
        Some("gateway") => cmd_gateway(&args),
        Some("export-weights") => cmd_export_weights(&args),
        Some("pjrt-smoke") => cmd_pjrt_smoke(&args),
        _ => usage(),
    }
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let anchors: usize = args.flag("anchors").and_then(|s| s.parse().ok()).unwrap_or(5);
    let ctx: usize = args.flag("ctx").and_then(|s| s.parse().ok()).unwrap_or(1536);
    let n_prompts: usize = args.flag("prompts").and_then(|s| s.parse().ok()).unwrap_or(4);
    let out = PathBuf::from(args.flag("out").unwrap_or("results/plan.json"));
    let spec = SynthSpec::eval_base(args.flag("seed").and_then(|s| s.parse().ok()).unwrap_or(42));
    let model = spec.build();
    let mut gen = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..n_prompts).map(|_| gen.dev_prompt(ctx)).collect();
    let cal = calibrate(
        &model,
        &prompts,
        &CalibrateOptions { anchors, ..Default::default() },
    );
    println!("anchors: {:?}", cal.plan.anchors);
    println!("objective: {:.4}", cal.plan.objective);
    println!("importance: {:?}", cal.importance);
    for (l, hm) in cal.plan.head_map.iter().enumerate() {
        println!("  layer {l:>2} ({:?}) head_map {:?}", cal.plan.role(l), hm);
    }
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    cal.plan.save(&out)?;
    println!("plan written to {}", out.display());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let n_requests: usize = args.flag("requests").and_then(|s| s.parse().ok()).unwrap_or(16);
    let ctx: usize = args.flag("ctx").and_then(|s| s.parse().ok()).unwrap_or(1024);
    let policy = args.flag("policy").unwrap_or("kascade").to_string();
    let spec = SynthSpec::eval_base(42);
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0x5E12E);
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let plan = if policy == "kascade" {
        let prompts: Vec<Vec<u32>> = (0..3).map(|_| dev.dev_prompt(ctx.min(1024))).collect();
        Some(calibrate(&model, &prompts, &CalibrateOptions::default()).plan)
    } else {
        None
    };
    let cap = ctx + 64;
    // tiered KV storage (docs/kv-tiers.md): int8 caches, reuse layers
    // under a hot-tile budget, cold tiles spilled to an append-only file
    let kv_tiers = args.has("kv-tiers");
    let hot_tile_budget: usize =
        args.flag("hot-tile-budget").and_then(|s| s.parse().ok()).unwrap_or(256);
    let store: Option<kascade::tilestore::SharedTileStore> = if kv_tiers {
        let path = args.flag("spill").unwrap_or("results/kv_spill.kvsp").to_string();
        // each run spills its own working set; a stale file only grows
        let _ = std::fs::remove_file(&path);
        Some(kascade::tilestore::shared_store(kascade::tilestore::FileTileStore::open(&path)?))
    } else {
        None
    };
    // --kv-dtype f32|f16|int8|int4 picks the KV storage mode; tiered
    // storage forces int8 (tiles spill as int8 payloads)
    let kv_dtype = if kv_tiers {
        if let Some(s) = args.flag("kv-dtype") {
            anyhow::ensure!(s == "int8", "--kv-tiers requires --kv-dtype int8 (got {s})");
        }
        kascade::config::KvDtype::Int8
    } else {
        match args.flag("kv-dtype") {
            None => kascade::config::KvDtype::F32,
            Some(s) => kascade::config::KvDtype::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown --kv-dtype {s} (f32|f16|int8|int4)"))?,
        }
    };
    let factory: BackendFactory = {
        let model = model.clone();
        Box::new(move |_req| {
            let policy: Box<dyn kascade::sparse::SparsePolicy> = match &plan {
                Some(p) => Box::new(KascadePolicy::new(p.clone())),
                None => Box::new(DensePolicy),
            };
            match &store {
                Some(st) => Box::new(NativeBackend::with_tiers(
                    model.clone(),
                    cap,
                    policy,
                    kascade::tilestore::TierParams::new(hot_tile_budget),
                    st,
                )),
                None => Box::new(NativeBackend::with_dtype(model.clone(), cap, policy, kv_dtype)),
            }
        })
    };
    let num_threads: usize = args.flag("threads").and_then(|s| s.parse().ok()).unwrap_or(1);
    let mut engine = Engine::new(
        ServeConfig {
            num_blocks: (cap / 16 + 2) * 32,
            num_threads,
            kv_dtype,
            kv_tiers,
            hot_tile_budget,
            ..ServeConfig::default()
        },
        factory,
    );
    let deadline_ms: Option<f64> = args.flag("deadline-ms").and_then(|s| s.parse().ok());
    let mut expected = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n_requests {
        let t = gen.longbench(kascade::workload::Category::Sqa, ctx);
        expected.push(t.expect.clone());
        let mut req = Request::new(t.prompt)
            .max_new(t.max_new)
            .stop(*t.expect.last().unwrap());
        if let Some(ms) = deadline_ms {
            req = req.deadline_ms(ms);
        }
        handles.push(engine.submit(req).expect("admission"));
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion(&mut handles);
    let secs = t0.elapsed().as_secs_f64();
    let mut correct = 0;
    for c in &done {
        if c.tokens.first() == expected[c.id as usize].first() {
            correct += 1;
        }
    }
    println!(
        "policy={policy} requests={n_requests} ctx={ctx} kv_tiers={kv_tiers}{}",
        if kv_tiers { format!(" hot_tile_budget={hot_tile_budget}") } else { String::new() }
    );
    println!("{}", engine.metrics.report());
    println!(
        "wall={secs:.1}s accuracy={:.0}% ({} of {})",
        100.0 * correct as f64 / n_requests as f64,
        correct,
        n_requests
    );
    Ok(())
}

/// Replay a seeded bursty multi-tenant traffic stream (RAG shared-prefix,
/// agentic multi-turn, long-document summarization) through the engine on
/// a null-compute backend, and report the TTFT/TPOT percentile surface —
/// the CLI face of the `slo_traffic` bench scenario, for poking at the
/// scheduler knobs (`--guard`, `--fair-share`) interactively.
fn cmd_traffic(args: &Args) -> anyhow::Result<()> {
    use kascade::coordinator::SeqBackend;
    use kascade::workload::{TrafficGen, TrafficSpec};

    /// O(1) backend: the harness measures the scheduling surface.
    struct NullBackend;
    impl SeqBackend for NullBackend {
        fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
            Some(vec![0.0, 1.0])
        }

        fn decode(&mut self, _token: u32) -> Vec<f32> {
            vec![0.0, 1.0]
        }
    }

    let seed: u64 = args.flag("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let ticks: usize = args.flag("ticks").and_then(|s| s.parse().ok()).unwrap_or(200);
    let rate: f64 = args.flag("rate").and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let burst_rate: f64 = args.flag("burst-rate").and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let prompt_cap: usize = args.flag("prompt-cap").and_then(|s| s.parse().ok()).unwrap_or(512);
    let guard: Option<usize> = args.flag("guard").and_then(|s| s.parse().ok());
    let fair_share = args.has("fair-share");
    let num_threads: usize = args.flag("threads").and_then(|s| s.parse().ok()).unwrap_or(1);

    let mut gen = TrafficGen::new(TrafficSpec {
        seed,
        base_rate: rate,
        burst_rate,
        prompt_cap,
        ..TrafficSpec::default()
    });
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 16384,
        max_running: 16,
        token_budget: 1024,
        prefill_chunk: 256,
        queue_cap: 1024,
        workers: 1,
        num_threads,
        fair_share,
        decode_guard_prefill_tokens: guard,
        ..ServeConfig::default()
    };
    let mut engine = Engine::new(
        cfg,
        Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>),
    );
    let mut handles = Vec::new();
    let mut by_class = std::collections::HashMap::<&'static str, usize>::new();
    let mut rejected = 0usize;
    let t0 = std::time::Instant::now();
    for _ in 0..ticks {
        for r in gen.next_tick() {
            *by_class.entry(r.class.name()).or_insert(0) += 1;
            match engine.submit(Request::new(r.prompt).max_new(r.max_new).tenant(r.tenant)) {
                Ok(h) => handles.push(h),
                Err(_) => rejected += 1,
            }
        }
        engine.tick();
    }
    let done = engine.run_to_completion(&mut handles);
    let wall = t0.elapsed().as_secs_f64();
    let m = &engine.metrics;
    println!(
        "traffic seed={seed} ticks={ticks} rate={rate} burst_rate={burst_rate} \
         fair_share={fair_share} guard={guard:?}"
    );
    let mut classes: Vec<_> = by_class.iter().collect();
    classes.sort();
    for (name, n) in classes {
        println!("  class {name:<8} {n} requests");
    }
    println!("  {} completions, {rejected} rejected, wall {wall:.2}s", done.len());
    println!("  {}", m.report());
    println!(
        "  ttft p50={:.2}ms p95={:.2}ms p99={:.2}ms  tpot p50={:.3}ms p95={:.3}ms p99={:.3}ms",
        m.ttft_percentile(50.0) / 1e3,
        m.ttft_percentile(95.0) / 1e3,
        m.ttft_percentile(99.0) / 1e3,
        m.tpot_percentile(50.0) / 1e3,
        m.tpot_percentile(95.0) / 1e3,
        m.tpot_percentile(99.0) / 1e3,
    );
    println!(
        "  prefill tokens/tick mean={:.1} max={:.0}",
        m.prefill_tokens_per_tick.mean(),
        m.prefill_tokens_per_tick.max()
    );
    Ok(())
}

/// Serve the HTTP gateway over N in-process replicas (docs/gateway.md),
/// on a null-compute backend with prefix-fork support so affinity
/// routing and prefix-cache resumes are observable without a model.
/// `--smoke` runs the CI loopback exercise: one streamed generation,
/// one affinity repeat, one graceful drain cycle — all on an ephemeral
/// port under a hard watchdog timeout — then exits 0.
fn cmd_gateway(args: &Args) -> anyhow::Result<()> {
    use kascade::coordinator::SeqBackend;
    use kascade::gateway::{http, Gateway, GatewayConfig, GatewayServer, ReplicaHealth};
    use kascade::jsonutil::Json;
    use kascade::server::Server;

    /// O(1) backend whose state is its token count; `fork_prefix`
    /// support makes prefix-cache snapshot resumes (and therefore
    /// affinity `prefix_hits`) real.
    struct ForkableNull {
        tokens: usize,
    }
    impl SeqBackend for ForkableNull {
        fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
            self.tokens += tokens.len();
            Some(vec![0.0, 1.0])
        }

        fn decode(&mut self, _token: u32) -> Vec<f32> {
            self.tokens += 1;
            vec![0.0, 1.0]
        }

        fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
            (tokens <= self.tokens)
                .then(|| Box::new(ForkableNull { tokens }) as Box<dyn SeqBackend>)
        }
    }

    let replicas: usize = args.flag("replicas").and_then(|s| s.parse().ok()).unwrap_or(2);
    let workers: usize = args.flag("workers").and_then(|s| s.parse().ok()).unwrap_or(1);
    let port: u16 = args.flag("port").and_then(|s| s.parse().ok()).unwrap_or(0);
    let affinity = !args.has("no-affinity");
    let smoke = args.has("smoke");
    let smoke_timeout_s: u64 =
        args.flag("smoke-timeout-s").and_then(|s| s.parse().ok()).unwrap_or(60);

    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 1024,
        max_running: 16,
        token_budget: 1024,
        prefill_chunk: 128,
        queue_cap: 256,
        enable_prefix_cache: true,
        prefix_cache_blocks: 512,
        ..ServeConfig::default()
    };
    let make_replica = {
        let cfg = cfg.clone();
        move || {
            let factories: Vec<BackendFactory> = (0..workers.max(1))
                .map(|_| {
                    Box::new(|_req: &Request| {
                        Box::new(ForkableNull { tokens: 0 }) as Box<dyn SeqBackend>
                    }) as BackendFactory
                })
                .collect();
            Server::start(cfg.clone(), factories)
        }
    };

    let gateway = Gateway::new(GatewayConfig {
        block_size: cfg.block_size,
        affinity,
        ..GatewayConfig::default()
    });
    for _ in 0..replicas.max(1) {
        gateway.join(make_replica());
    }
    gateway.set_spawner(Box::new(make_replica));
    let server = GatewayServer::bind(&format!("127.0.0.1:{port}"), gateway)?;
    let addr = server.addr().to_string();
    println!(
        "gateway listening on {addr} ({} replicas x {} workers, affinity={affinity})",
        replicas.max(1),
        workers.max(1)
    );

    if !smoke {
        println!("endpoints: POST /v1/generate, GET /healthz, GET /metrics, POST /admin/drain");
        println!("serving until killed (ctrl-c)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // hard watchdog: a wedged stream/drain must fail the smoke, not hang CI
    std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_secs(smoke_timeout_s));
        eprintln!("gateway smoke timed out after {smoke_timeout_s}s");
        std::process::exit(3);
    });

    let prompt: Vec<Json> = (0..48u32).map(Json::num).collect();
    let body = Json::obj(vec![
        ("prompt", Json::arr(prompt)),
        ("max_new", Json::num(8u32)),
    ])
    .to_string();
    let run_stream = || -> anyhow::Result<(usize, usize)> {
        let mut stream = http::NdjsonStream::post(&addr, "/v1/generate", body.as_bytes())?;
        anyhow::ensure!(stream.status == 200, "generate status {}", stream.status);
        let lines = stream.collect_lines()?;
        let routed = lines
            .first()
            .and_then(|l| Json::parse(l).ok())
            .and_then(|j| j.get("replica").and_then(Json::as_usize))
            .ok_or_else(|| anyhow::anyhow!("missing routed line"))?;
        anyhow::ensure!(
            lines.last().is_some_and(|l| l.contains("\"done\"")),
            "stream did not end in done: {lines:?}"
        );
        Ok((routed, lines.len()))
    };
    let (first_replica, n_lines) = run_stream()?;
    let (second_replica, _) = run_stream()?;
    println!(
        "smoke: streamed {n_lines} events; routed replica {first_replica} then {second_replica}"
    );
    if affinity {
        anyhow::ensure!(
            first_replica == second_replica,
            "affinity failed to pin the shared prefix to one replica"
        );
    }

    // one drain cycle: the drained replica retires, the fleet still admits
    let drain_body = Json::obj(vec![("replica", Json::num(first_replica as u32))]).to_string();
    let resp = http::request(&addr, "POST", "/admin/drain", drain_body.as_bytes())?;
    anyhow::ensure!(resp.status == 200, "drain status {}", resp.status);
    anyhow::ensure!(
        resp.text().contains("\"dead\""),
        "drain did not retire the replica: {}",
        resp.text()
    );
    let health = http::request(&addr, "GET", "/healthz", b"")?;
    anyhow::ensure!(
        health.status == 200,
        "fleet stopped admitting after a single-replica drain"
    );
    // a post-drain generation must land on a surviving replica
    let (post_drain_replica, _) = run_stream()?;
    anyhow::ensure!(post_drain_replica != first_replica, "routed to a dead replica");
    let metrics = http::request(&addr, "GET", "/metrics", b"")?;
    anyhow::ensure!(metrics.status == 200, "metrics status {}", metrics.status);
    println!("smoke: metrics {}", metrics.text().trim());

    let gw = server.gateway();
    server.stop();
    for s in gw.statuses() {
        if s.health != ReplicaHealth::Dead {
            gw.drain(s.id);
            gw.wait_drained(s.id, 10_000);
        }
    }
    println!("gateway smoke OK");
    Ok(())
}

fn cmd_export_weights(args: &Args) -> anyhow::Result<()> {
    let out = PathBuf::from(args.flag("out").unwrap_or("artifacts/synth_weights"));
    let seed = args.flag("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let spec = SynthSpec::pjrt_small(seed);
    let model = spec.build();
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    model.w.export_bin(&model.cfg, &out)?;
    println!("wrote {}.bin / .json", out.display());
    Ok(())
}

fn cmd_pjrt_smoke(args: &Args) -> anyhow::Result<()> {
    use kascade::runtime::{PjrtModel, Runtime};
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    let rt = Runtime::load(&dir)?;
    println!(
        "manifest: {} artifacts, decode buckets {:?}, prefill buckets {:?}",
        rt.manifest.artifacts.len(),
        rt.manifest.decode_l,
        rt.manifest.prefill_t
    );
    let spec = SynthSpec::pjrt_small(42);
    let native = spec.build();
    let pjrt = PjrtModel::new(rt, &native.w)?;
    // parity: one small dense prefill through both paths
    let lay = spec.vocab_layout();
    let mut toks = vec![kascade::model::VocabLayout::BOS];
    for f in 0..100 {
        toks.push(lay.filler_tok(f));
    }
    toks[40] = lay.pair_tok(3, 7);
    toks.push(kascade::model::VocabLayout::QUERY);
    toks.push(lay.key_tok(3));
    let mut pst = pjrt.new_state();
    let pjrt_logits = pjrt.prefill(&toks, &mut pst, None)?;
    let mut nst = native.new_state(toks.len() + 8);
    let (native_logits, _) = native.prefill(&toks, &mut nst, &mut DensePolicy, None);
    let pa = kascade::tensor::argmax(&pjrt_logits);
    let na = kascade::tensor::argmax(&native_logits);
    let mut max_diff = 0.0f32;
    for (a, b) in pjrt_logits.iter().zip(&native_logits) {
        max_diff = max_diff.max((a - b).abs());
    }
    println!("argmax pjrt={pa} native={na} expected={} max|Δlogit|={max_diff:.4}", lay.value_tok(7));
    anyhow::ensure!(pa == na, "parity failure");
    anyhow::ensure!(pa as u32 == lay.value_tok(7), "retrieval failure on PJRT path");
    // decode parity for a few steps
    let tok = pa as u32;
    let p2 = pjrt.decode_step(tok, &mut pst, None)?;
    let n2 = native.decode_step(tok, &mut nst, &mut DensePolicy);
    anyhow::ensure!(
        kascade::tensor::argmax(&p2) == kascade::tensor::argmax(&n2),
        "decode parity failure"
    );
    println!("pjrt-smoke OK ({} executables compiled)", pjrt.rt.compiled_count());
    Ok(())
}
