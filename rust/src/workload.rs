//! Synthetic benchmark suites over SynthLM (DESIGN.md §2 substitutions):
//!
//! * **LongBench-S** — six prefill-heavy categories mirroring LongBench's
//!   structure (SQA, MQA, Summ, Fewshot, Synthetic, Code), each a
//!   retrieval/aggregation task with a known answer.
//! * **AIME-S** — decode-heavy multi-hop chain-following tasks (the AIME-24
//!   substitute): the model must iteratively retrieve the next hop during a
//!   long decode; errors break or lengthen the chain.
//! * **DevSet** — MuSiQue-substitute prompts for Kascade calibration.
//!
//! Plus the production traffic harness (ROADMAP item 5): [`TrafficGen`],
//! a deterministic seeded generator of bursty/diurnal multi-tenant
//! serving load (heavy-tailed prompt/output lengths; RAG shared-prefix,
//! agentic multi-turn and long-document-summarization tenants) that
//! drives the streaming `Request`/`Event` API in benches and tests.

use crate::model::{SynthSpec, VocabLayout};
use crate::tensor::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Sqa,
    Mqa,
    Summ,
    Fewshot,
    Synthetic,
    Code,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Sqa,
        Category::Mqa,
        Category::Summ,
        Category::Fewshot,
        Category::Synthetic,
        Category::Code,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Sqa => "SQA",
            Category::Mqa => "MQA",
            Category::Summ => "Summ.",
            Category::Fewshot => "Fewshot",
            Category::Synthetic => "Synthetic",
            Category::Code => "Code",
        }
    }
}

/// A task instance: prompt + expected greedy continuation.
#[derive(Debug, Clone)]
pub struct Task {
    pub prompt: Vec<u32>,
    /// Expected emitted tokens, in order (graded prefix-exact).
    pub expect: Vec<u32>,
    /// Decode budget (cap).
    pub max_new: usize,
    /// Ground-truth chain length (AIME-S; 0 otherwise).
    pub hops: usize,
}

pub struct WorkloadGen {
    pub lay: VocabLayout,
    rng: Rng,
}

impl WorkloadGen {
    pub fn new(spec: &SynthSpec, seed: u64) -> Self {
        Self { lay: spec.vocab_layout(), rng: Rng::new(seed) }
    }

    fn filler_run(&mut self, out: &mut Vec<u32>, n: usize, low_entropy: bool) {
        if low_entropy {
            // "code"-like: short repeating motifs
            let motif: Vec<usize> = (0..4).map(|_| self.rng.below(self.lay.n_filler())).collect();
            for i in 0..n {
                out.push(self.lay.filler_tok(motif[i % motif.len()] + (i / 16) % 3));
            }
        } else {
            for _ in 0..n {
                out.push(self.lay.filler_tok(self.rng.below(self.lay.n_filler())));
            }
        }
    }

    /// Non-terminal entity (terminal is reserved for chains).
    fn entity(&mut self) -> usize {
        self.rng.below(self.lay.n_entities - 1)
    }

    fn distinct_entities(&mut self, n: usize) -> Vec<usize> {
        let mut pool: Vec<usize> = (0..self.lay.n_entities - 1).collect();
        self.rng.shuffle(&mut pool);
        pool.truncate(n);
        pool
    }

    /// Place `tok` at a random interior position of `toks` (never in the
    /// final `tail_guard` tokens).
    fn plant(&mut self, toks: &mut [u32], tok: u32, tail_guard: usize) -> usize {
        let hi = toks.len().saturating_sub(tail_guard).max(2);
        let pos = 1 + self.rng.below(hi - 1);
        toks[pos] = tok;
        pos
    }

    /// One LongBench-S task of `cat` with ~`ctx` prompt tokens.
    pub fn longbench(&mut self, cat: Category, ctx: usize) -> Task {
        let lay = self.lay;
        let mut toks = vec![VocabLayout::BOS];
        let body = ctx.saturating_sub(4);
        match cat {
            Category::Sqa => {
                // single needle, uniform position, random filler
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                self.plant(&mut toks, lay.pair_tok(i, j), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
            Category::Mqa => {
                // 2-hop: answer requires composing two facts
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(3);
                let (a, b, c) = (es[0], es[1], es[2]);
                self.plant(&mut toks, lay.pair_tok(a, b), 16);
                self.plant(&mut toks, lay.pair_tok(b, c), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(a));
                Task {
                    prompt: toks,
                    expect: vec![lay.value_tok(b), lay.value_tok(c)],
                    max_new: 3,
                    hops: 2,
                }
            }
            Category::Summ => {
                // majority aggregation: repeated binding wins
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(3);
                let (i, maj, min_) = (es[0], es[1], es[2]);
                for _ in 0..4 {
                    self.plant(&mut toks, lay.pair_tok(i, maj), 16);
                }
                self.plant(&mut toks, lay.pair_tok(i, min_), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(maj)], max_new: 2, hops: 1 }
            }
            Category::Fewshot => {
                // dense example list; query one mapping among many
                self.filler_run(&mut toks, body, false);
                let n_pairs = 12.min((self.lay.n_entities - 1) / 2);
                let es = self.distinct_entities(2 * n_pairs);
                let mut target = (es[0], es[1]);
                for p in 0..n_pairs {
                    let (i, j) = (es[2 * p], es[2 * p + 1]);
                    let pos = self.plant(&mut toks, lay.pair_tok(i, j), 16);
                    if p == n_pairs / 2 {
                        target = (i, j);
                        let _ = pos;
                    }
                }
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(target.0));
                Task { prompt: toks, expect: vec![lay.value_tok(target.1)], max_new: 2, hops: 1 }
            }
            Category::Synthetic => {
                // passkey: needle in near-uniform PAD-ish noise
                let motif = self.rng.below(self.lay.n_filler());
                for i in 0..body {
                    toks.push(self.lay.filler_tok(motif + (i % 2)));
                }
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                self.plant(&mut toks, lay.pair_tok(i, j), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
            Category::Code => {
                // definition lookup in low-entropy (code-like) filler;
                // needle biased toward the beginning of the file
                self.filler_run(&mut toks, body, true);
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                let pos = 1 + self.rng.below((toks.len() / 4).max(2));
                toks[pos] = lay.pair_tok(i, j);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
        }
    }

    /// One AIME-S chain task: `hops` facts scattered in context; the decode
    /// must walk key -> value -> ... -> TERM.
    pub fn aime(&mut self, ctx: usize, hops: usize) -> Task {
        let lay = self.lay;
        let term = lay.term_entity();
        // chain entities: e0 -> e1 -> ... -> e_{hops-1} -> term
        let mut ents = self.distinct_entities(hops);
        ents.push(term);
        let mut toks = vec![VocabLayout::BOS];
        self.filler_run(&mut toks, ctx.saturating_sub(4), false);
        for w in ents.windows(2) {
            self.plant(&mut toks, lay.pair_tok(w[0], w[1]), 16);
        }
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(ents[0]));
        let expect: Vec<u32> = ents[1..].iter().map(|&e| lay.value_tok(e)).collect();
        Task { prompt: toks, expect, max_new: hops * 3 + 8, hops }
    }

    /// Shared-prefix RAG suite (the prefix-cache workload): `n` requests
    /// over one shared ~`shared_ctx`-token document (system prompt +
    /// retrieved corpus with `n` planted facts), each with a unique
    /// ~`unique_ctx`-token tail and a query for its own fact in the
    /// shared document.  All prompts share an identical token prefix of
    /// `shared_ctx` tokens, so with prefix caching enabled only the
    /// first request pays the document prefill.
    pub fn rag_suite(&mut self, n: usize, shared_ctx: usize, unique_ctx: usize) -> Vec<Task> {
        let lay = self.lay;
        assert!(2 * n < lay.n_entities, "too many requests for the entity pool");
        let mut doc = vec![VocabLayout::BOS];
        self.filler_run(&mut doc, shared_ctx.saturating_sub(1), false);
        let es = self.distinct_entities(2 * n);
        let mut facts = Vec::with_capacity(n);
        let mut used = Vec::new();
        // interior positions [1, hi): the retry loop below needs at
        // least n distinct ones or it would never terminate
        let hi = doc.len().saturating_sub(16).max(2);
        assert!(n < hi, "shared document too small for {n} distinct facts");
        for i in 0..n {
            let (a, b) = (es[2 * i], es[2 * i + 1]);
            // plant at a distinct interior position (never clobber an
            // earlier fact, never in the final guard region)
            let mut pos = 1 + self.rng.below(hi - 1);
            while used.contains(&pos) {
                pos = 1 + self.rng.below(hi - 1);
            }
            used.push(pos);
            doc[pos] = lay.pair_tok(a, b);
            facts.push((a, b));
        }
        (0..n)
            .map(|i| {
                let mut toks = doc.clone();
                self.filler_run(&mut toks, unique_ctx, false);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(facts[i].0));
                Task {
                    prompt: toks,
                    expect: vec![lay.value_tok(facts[i].1)],
                    max_new: 2,
                    hops: 1,
                }
            })
            .collect()
    }

    /// Calibration prompt (MuSiQue substitute): mixed retrieval content.
    pub fn dev_prompt(&mut self, ctx: usize) -> Vec<u32> {
        let lay = self.lay;
        let mut toks = vec![VocabLayout::BOS];
        self.filler_run(&mut toks, ctx.saturating_sub(4), false);
        for _ in 0..4 {
            let es = self.distinct_entities(2);
            self.plant(&mut toks, lay.pair_tok(es[0], es[1]), 8);
        }
        let e = self.entity();
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(e));
        toks
    }
}

/// Tenant classes in the production traffic mix, each with its own
/// request shape (see [`TrafficGen`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TenantClass {
    /// Many requests over one shared document prefix + short unique
    /// tails and short answers — the prefix-cache workload.
    RagSharedPrefix,
    /// Conversations that grow turn over turn: each request's prompt is
    /// the session history (sharing a prefix with the previous turn)
    /// plus fresh user tokens; moderate outputs.
    AgenticMultiTurn,
    /// Heavy-tailed long documents with longer summaries — the prefill
    /// pressure that decode-tick protection exists for.
    LongDocSumm,
}

impl TenantClass {
    pub const ALL: [TenantClass; 3] = [
        TenantClass::RagSharedPrefix,
        TenantClass::AgenticMultiTurn,
        TenantClass::LongDocSumm,
    ];

    /// Stable tenant id for fair-share admission accounting.
    pub fn tenant(&self) -> u32 {
        match self {
            TenantClass::RagSharedPrefix => 0,
            TenantClass::AgenticMultiTurn => 1,
            TenantClass::LongDocSumm => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TenantClass::RagSharedPrefix => "rag",
            TenantClass::AgenticMultiTurn => "agentic",
            TenantClass::LongDocSumm => "summ",
        }
    }
}

/// Knobs of the traffic generator.  Every sample is a pure function of
/// `seed` and the knobs, so a run is replayable tick for tick.
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    pub seed: u64,
    /// Mean request arrivals per tick at the diurnal baseline
    /// (Poisson-distributed per tick).
    pub base_rate: f64,
    /// Rate multiplier while a burst episode is active.
    pub burst_rate: f64,
    /// Per-tick probability that a burst episode starts.
    pub burst_prob: f64,
    /// Burst episode length in ticks.
    pub burst_ticks: usize,
    /// Diurnal cycle period in ticks: the arrival rate is modulated by
    /// `1 + 0.5 sin(2πt / period)` (a 3:1 peak-to-trough swing).
    pub diurnal_period: usize,
    /// Heavy-tailed prompt lengths: Pareto(`prompt_alpha`) with scale
    /// `prompt_min`, truncated at `prompt_cap` (summarization tenants
    /// scale min/cap by `summ_factor`).
    pub prompt_min: usize,
    pub prompt_alpha: f64,
    pub prompt_cap: usize,
    /// Heavy-tailed output lengths (same Pareto shape family).
    pub output_min: usize,
    pub output_alpha: f64,
    pub output_cap: usize,
    /// Relative tenant weights `[rag, agentic, summ]`.
    pub mix: [u32; 3],
    /// Shared RAG document length in tokens (identical across all
    /// RAG requests from one generator).
    pub shared_prefix_len: usize,
    /// Prompt length multiplier for the summarization tenant.
    pub summ_factor: usize,
    /// Concurrent agentic sessions whose histories grow turn over turn.
    pub agentic_sessions: usize,
    /// Token id range for generated prompts.
    pub vocab: u32,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        Self {
            seed: 0,
            base_rate: 1.0,
            burst_rate: 4.0,
            burst_prob: 0.05,
            burst_ticks: 8,
            diurnal_period: 256,
            prompt_min: 32,
            prompt_alpha: 1.2,
            prompt_cap: 2048,
            output_min: 4,
            output_alpha: 1.5,
            output_cap: 64,
            mix: [3, 2, 1],
            shared_prefix_len: 128,
            summ_factor: 4,
            agentic_sessions: 4,
            vocab: 64,
        }
    }
}

/// One generated arrival: feed `prompt`/`max_new`/`tenant` into a
/// [`crate::coordinator::Request`] at tick `at_tick`.
#[derive(Debug, Clone)]
pub struct TrafficRequest {
    pub at_tick: u64,
    pub class: TenantClass,
    pub tenant: u32,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Deterministic bursty/diurnal multi-tenant traffic generator.
///
/// Arrivals per tick are Poisson at a rate shaped by a sinusoidal
/// diurnal cycle and random burst episodes; prompt and output lengths
/// are truncated-Pareto (heavy-tailed — most requests are small, the
/// tail is what stresses chunked prefill); the tenant mix interleaves
/// RAG shared-prefix, agentic multi-turn and long-document
/// summarization request shapes.  Same [`TrafficSpec`] (seed included)
/// ⇒ bitwise-identical arrival/length/token streams.
pub struct TrafficGen {
    pub spec: TrafficSpec,
    rng: Rng,
    tick: u64,
    burst_left: usize,
    shared_doc: Vec<u32>,
    agent_hist: Vec<Vec<u32>>,
}

impl TrafficGen {
    pub fn new(spec: TrafficSpec) -> Self {
        let mut rng = Rng::new(spec.seed);
        let vocab = spec.vocab.max(2);
        let shared_doc: Vec<u32> =
            (0..spec.shared_prefix_len).map(|_| rng.below(vocab as usize) as u32).collect();
        let agent_hist = vec![Vec::new(); spec.agentic_sessions.max(1)];
        Self { spec, rng, tick: 0, burst_left: 0, shared_doc, agent_hist }
    }

    /// Uniform draw in [0, 1) off the seeded generator.
    fn unit(&mut self) -> f64 {
        self.rng.below(1 << 20) as f64 / (1u64 << 20) as f64
    }

    /// Poisson(`lambda`) via Knuth's product-of-uniforms (fine for the
    /// single-digit per-tick rates this harness uses).
    fn poisson(&mut self, lambda: f64) -> usize {
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0f64;
        loop {
            p *= self.unit();
            if p <= l || k > 10_000 {
                return k;
            }
            k += 1;
        }
    }

    /// Truncated Pareto: `min / (1-u)^(1/alpha)`, capped at `cap`.
    fn pareto(&mut self, min: usize, alpha: f64, cap: usize) -> usize {
        let u = self.unit().min(1.0 - 1e-12);
        let x = min as f64 / (1.0 - u).powf(1.0 / alpha);
        (x as usize).clamp(min.max(1), cap.max(min.max(1)))
    }

    fn tokens(&mut self, n: usize) -> Vec<u32> {
        let v = self.spec.vocab.max(2) as usize;
        (0..n).map(|_| self.rng.below(v) as u32).collect()
    }

    fn pick_class(&mut self) -> TenantClass {
        let total: u32 = self.spec.mix.iter().sum::<u32>().max(1);
        let mut r = self.rng.below(total as usize) as u32;
        for (i, &w) in self.spec.mix.iter().enumerate() {
            if r < w {
                return TenantClass::ALL[i];
            }
            r -= w;
        }
        TenantClass::ALL[2]
    }

    /// Effective arrival rate for tick `t` (diurnal × burst shaping).
    fn rate_at(&self, t: u64) -> f64 {
        let period = self.spec.diurnal_period.max(1) as f64;
        let diurnal = 1.0 + 0.5 * (2.0 * std::f64::consts::PI * t as f64 / period).sin();
        let burst = if self.burst_left > 0 { self.spec.burst_rate } else { 1.0 };
        self.spec.base_rate * diurnal * burst
    }

    fn request_for(&mut self, class: TenantClass, at_tick: u64) -> TrafficRequest {
        let s = self.spec.clone();
        let (prompt, max_new) = match class {
            TenantClass::RagSharedPrefix => {
                let tail = self.pareto(s.prompt_min, s.prompt_alpha, s.prompt_cap);
                let mut p = self.shared_doc.clone();
                p.extend(self.tokens(tail));
                let out = self.pareto(s.output_min, s.output_alpha, s.output_cap);
                (p, out)
            }
            TenantClass::AgenticMultiTurn => {
                let sess = self.rng.below(self.agent_hist.len());
                let user = self.pareto(s.prompt_min, s.prompt_alpha, s.prompt_cap);
                let fresh = self.tokens(user);
                self.agent_hist[sess].extend(fresh);
                let prompt = self.agent_hist[sess].clone();
                let out = self.pareto(s.output_min, s.output_alpha, s.output_cap);
                // the (placeholder) assistant turn grows the history, so
                // the next request from this session shares this
                // request's prompt as a strict prefix
                let reply = self.tokens(out);
                self.agent_hist[sess].extend(reply);
                (prompt, out)
            }
            TenantClass::LongDocSumm => {
                let f = s.summ_factor.max(1);
                let len = self.pareto(s.prompt_min * f, s.prompt_alpha, s.prompt_cap * f);
                let out =
                    self.pareto(s.output_min * 2, s.output_alpha, s.output_cap * 2);
                (self.tokens(len), out)
            }
        };
        TrafficRequest { at_tick, class, tenant: class.tenant(), prompt, max_new: max_new.max(1) }
    }

    /// Arrivals for the next tick (advances the generator's clock).
    pub fn next_tick(&mut self) -> Vec<TrafficRequest> {
        let t = self.tick;
        self.tick += 1;
        if self.burst_left > 0 {
            self.burst_left -= 1;
        } else if self.unit() < self.spec.burst_prob {
            self.burst_left = self.spec.burst_ticks;
        }
        let lambda = self.rate_at(t);
        let n = self.poisson(lambda);
        (0..n)
            .map(|_| {
                let class = self.pick_class();
                self.request_for(class, t)
            })
            .collect()
    }

    /// All arrivals over `ticks` ticks, in arrival order.
    pub fn generate(&mut self, ticks: usize) -> Vec<TrafficRequest> {
        let mut out = Vec::new();
        for _ in 0..ticks {
            out.extend(self.next_tick());
        }
        out
    }
}

/// Grade a decode against a task: full credit iff the expected sequence is
/// a prefix of the emission; AIME-S additionally requires termination.
pub fn grade(task: &Task, emitted: &[u32]) -> bool {
    if emitted.len() < task.expect.len() {
        return false;
    }
    emitted[..task.expect.len()] == task.expect[..]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SynthSpec;

    fn spec() -> SynthSpec {
        let mut s = SynthSpec::eval_base(1);
        s.cfg.n_layers = 4;
        s.block_starts = vec![1];
        s
    }

    #[test]
    fn prompts_have_requested_shape() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 3);
        for cat in Category::ALL {
            let t = g.longbench(cat, 512);
            assert!(t.prompt.len() >= 500 && t.prompt.len() <= 520, "{cat:?}");
            assert_eq!(t.prompt[0], VocabLayout::BOS);
            assert_eq!(t.prompt[t.prompt.len() - 2], VocabLayout::QUERY);
            assert!(!t.expect.is_empty());
        }
    }

    #[test]
    fn needle_is_present_and_interior() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 4);
        let t = g.longbench(Category::Sqa, 512);
        let lay = g.lay;
        // exactly one pair token, and it maps query key -> expected value
        let key = t.prompt[t.prompt.len() - 1];
        let i = (key - 16) as usize;
        let j = lay.value_entity(t.expect[0]).unwrap();
        let pair = lay.pair_tok(i, j);
        let count = t.prompt.iter().filter(|&&x| x == pair).count();
        assert_eq!(count, 1);
        let pos = t.prompt.iter().position(|&x| x == pair).unwrap();
        assert!(pos > 0 && pos < t.prompt.len() - 16);
    }

    #[test]
    fn aime_chain_is_consistent() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 5);
        let t = g.aime(1024, 6);
        assert_eq!(t.expect.len(), 6);
        assert_eq!(
            g.lay.value_entity(*t.expect.last().unwrap()),
            Some(g.lay.term_entity())
        );
        // each hop's pair token is present
        let key = t.prompt[t.prompt.len() - 1];
        let mut cur = (key - 16) as usize;
        for &v in &t.expect {
            let nxt = g.lay.value_entity(v).unwrap();
            assert!(t.prompt.contains(&g.lay.pair_tok(cur, nxt)), "missing hop {cur}->{nxt}");
            cur = nxt;
        }
    }

    #[test]
    fn grading() {
        let t = Task { prompt: vec![], expect: vec![5, 6], max_new: 4, hops: 2 };
        assert!(grade(&t, &[5, 6]));
        assert!(grade(&t, &[5, 6, 9]));
        assert!(!grade(&t, &[5]));
        assert!(!grade(&t, &[6, 5]));
    }

    #[test]
    fn rag_suite_shares_an_identical_prefix() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 7);
        let tasks = g.rag_suite(4, 256, 32);
        assert_eq!(tasks.len(), 4);
        let shared = tasks[0].prompt[..256].to_vec();
        for t in &tasks {
            assert_eq!(&t.prompt[..256], &shared[..], "identical shared document");
            assert!(t.prompt.len() >= 256 + 32);
            assert_eq!(t.prompt[t.prompt.len() - 2], VocabLayout::QUERY);
            // the queried fact lives in the shared document
            let key = t.prompt[t.prompt.len() - 1];
            let i = (key - 16) as usize;
            let j = g.lay.value_entity(t.expect[0]).unwrap();
            assert_eq!(shared.iter().filter(|&&x| x == g.lay.pair_tok(i, j)).count(), 1);
        }
        // each request queries a distinct fact
        let keys: std::collections::HashSet<u32> =
            tasks.iter().map(|t| *t.prompt.last().unwrap()).collect();
        assert_eq!(keys.len(), 4);
    }

    #[test]
    fn generator_is_deterministic() {
        let s = spec();
        let a = WorkloadGen::new(&s, 9).longbench(Category::Mqa, 256);
        let b = WorkloadGen::new(&s, 9).longbench(Category::Mqa, 256);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.expect, b.expect);
    }

    #[test]
    fn traffic_same_seed_replays_identical_streams() {
        let spec = TrafficSpec { seed: 41, base_rate: 2.0, ..TrafficSpec::default() };
        let a = TrafficGen::new(spec.clone()).generate(300);
        let b = TrafficGen::new(spec.clone()).generate(300);
        assert!(!a.is_empty(), "300 ticks at rate 2 must produce arrivals");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_tick, y.at_tick);
            assert_eq!(x.class, y.class);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
        }
        // a different seed must actually change the stream
        let c = TrafficGen::new(TrafficSpec { seed: 42, ..spec }).generate(300);
        let same = a.len() == c.len()
            && a.iter().zip(&c).all(|(x, y)| x.prompt == y.prompt && x.at_tick == y.at_tick);
        assert!(!same, "different seeds produced identical traffic");
    }

    #[test]
    fn traffic_shapes_match_tenant_classes() {
        let spec = TrafficSpec { seed: 11, base_rate: 3.0, ..TrafficSpec::default() };
        let shared_len = spec.shared_prefix_len;
        let reqs = TrafficGen::new(spec.clone()).generate(400);
        let mut seen = std::collections::HashSet::new();
        let mut rag_prefix: Option<Vec<u32>> = None;
        for r in &reqs {
            seen.insert(r.class);
            assert!(r.max_new >= 1);
            assert_eq!(r.tenant, r.class.tenant());
            if r.class == TenantClass::RagSharedPrefix {
                assert!(r.prompt.len() > shared_len);
                let p = r.prompt[..shared_len].to_vec();
                if let Some(ref first) = rag_prefix {
                    assert_eq!(&p, first, "all RAG requests share one document");
                } else {
                    rag_prefix = Some(p);
                }
            }
        }
        assert_eq!(seen.len(), 3, "the mix must exercise all tenant classes");
        // heavy tail: the summarization tenant's longest prompt dwarfs
        // the median RAG tail
        let max_summ = reqs
            .iter()
            .filter(|r| r.class == TenantClass::LongDocSumm)
            .map(|r| r.prompt.len())
            .max()
            .unwrap();
        assert!(max_summ > 2 * spec.prompt_min * spec.summ_factor, "no heavy tail: {max_summ}");
    }

    #[test]
    fn traffic_agentic_turns_share_a_growing_prefix() {
        // single agentic session: every turn's prompt must extend the
        // previous turn's prompt (the prefix-cache-friendly shape)
        let spec = TrafficSpec {
            seed: 5,
            base_rate: 2.0,
            mix: [0, 1, 0],
            agentic_sessions: 1,
            ..TrafficSpec::default()
        };
        let reqs = TrafficGen::new(spec).generate(100);
        assert!(reqs.len() >= 3);
        for w in reqs.windows(2) {
            let (a, b) = (&w[0].prompt, &w[1].prompt);
            assert!(b.len() > a.len(), "histories grow turn over turn");
            assert_eq!(&b[..a.len()], &a[..], "turn extends the previous prompt");
        }
    }

    #[test]
    fn traffic_bursts_and_diurnal_cycle_shape_the_rate() {
        // burst episodes force arrival clumps well above the baseline
        let spec = TrafficSpec {
            seed: 3,
            base_rate: 0.5,
            burst_rate: 8.0,
            burst_prob: 0.02,
            ..TrafficSpec::default()
        };
        let mut g = TrafficGen::new(spec);
        let mut per_tick = Vec::new();
        for _ in 0..1000 {
            per_tick.push(g.next_tick().len());
        }
        let max = *per_tick.iter().max().unwrap();
        let mean = per_tick.iter().sum::<usize>() as f64 / per_tick.len() as f64;
        assert!(max as f64 > 3.0 * mean.max(0.1), "no bursts: max {max}, mean {mean:.2}");
    }
}
