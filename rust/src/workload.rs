//! Synthetic benchmark suites over SynthLM (DESIGN.md §2 substitutions):
//!
//! * **LongBench-S** — six prefill-heavy categories mirroring LongBench's
//!   structure (SQA, MQA, Summ, Fewshot, Synthetic, Code), each a
//!   retrieval/aggregation task with a known answer.
//! * **AIME-S** — decode-heavy multi-hop chain-following tasks (the AIME-24
//!   substitute): the model must iteratively retrieve the next hop during a
//!   long decode; errors break or lengthen the chain.
//! * **DevSet** — MuSiQue-substitute prompts for Kascade calibration.

use crate::model::{SynthSpec, VocabLayout};
use crate::tensor::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Sqa,
    Mqa,
    Summ,
    Fewshot,
    Synthetic,
    Code,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Sqa,
        Category::Mqa,
        Category::Summ,
        Category::Fewshot,
        Category::Synthetic,
        Category::Code,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Sqa => "SQA",
            Category::Mqa => "MQA",
            Category::Summ => "Summ.",
            Category::Fewshot => "Fewshot",
            Category::Synthetic => "Synthetic",
            Category::Code => "Code",
        }
    }
}

/// A task instance: prompt + expected greedy continuation.
#[derive(Debug, Clone)]
pub struct Task {
    pub prompt: Vec<u32>,
    /// Expected emitted tokens, in order (graded prefix-exact).
    pub expect: Vec<u32>,
    /// Decode budget (cap).
    pub max_new: usize,
    /// Ground-truth chain length (AIME-S; 0 otherwise).
    pub hops: usize,
}

pub struct WorkloadGen {
    pub lay: VocabLayout,
    rng: Rng,
}

impl WorkloadGen {
    pub fn new(spec: &SynthSpec, seed: u64) -> Self {
        Self { lay: spec.vocab_layout(), rng: Rng::new(seed) }
    }

    fn filler_run(&mut self, out: &mut Vec<u32>, n: usize, low_entropy: bool) {
        if low_entropy {
            // "code"-like: short repeating motifs
            let motif: Vec<usize> = (0..4).map(|_| self.rng.below(self.lay.n_filler())).collect();
            for i in 0..n {
                out.push(self.lay.filler_tok(motif[i % motif.len()] + (i / 16) % 3));
            }
        } else {
            for _ in 0..n {
                out.push(self.lay.filler_tok(self.rng.below(self.lay.n_filler())));
            }
        }
    }

    /// Non-terminal entity (terminal is reserved for chains).
    fn entity(&mut self) -> usize {
        self.rng.below(self.lay.n_entities - 1)
    }

    fn distinct_entities(&mut self, n: usize) -> Vec<usize> {
        let mut pool: Vec<usize> = (0..self.lay.n_entities - 1).collect();
        self.rng.shuffle(&mut pool);
        pool.truncate(n);
        pool
    }

    /// Place `tok` at a random interior position of `toks` (never in the
    /// final `tail_guard` tokens).
    fn plant(&mut self, toks: &mut [u32], tok: u32, tail_guard: usize) -> usize {
        let hi = toks.len().saturating_sub(tail_guard).max(2);
        let pos = 1 + self.rng.below(hi - 1);
        toks[pos] = tok;
        pos
    }

    /// One LongBench-S task of `cat` with ~`ctx` prompt tokens.
    pub fn longbench(&mut self, cat: Category, ctx: usize) -> Task {
        let lay = self.lay;
        let mut toks = vec![VocabLayout::BOS];
        let body = ctx.saturating_sub(4);
        match cat {
            Category::Sqa => {
                // single needle, uniform position, random filler
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                self.plant(&mut toks, lay.pair_tok(i, j), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
            Category::Mqa => {
                // 2-hop: answer requires composing two facts
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(3);
                let (a, b, c) = (es[0], es[1], es[2]);
                self.plant(&mut toks, lay.pair_tok(a, b), 16);
                self.plant(&mut toks, lay.pair_tok(b, c), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(a));
                Task {
                    prompt: toks,
                    expect: vec![lay.value_tok(b), lay.value_tok(c)],
                    max_new: 3,
                    hops: 2,
                }
            }
            Category::Summ => {
                // majority aggregation: repeated binding wins
                self.filler_run(&mut toks, body, false);
                let es = self.distinct_entities(3);
                let (i, maj, min_) = (es[0], es[1], es[2]);
                for _ in 0..4 {
                    self.plant(&mut toks, lay.pair_tok(i, maj), 16);
                }
                self.plant(&mut toks, lay.pair_tok(i, min_), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(maj)], max_new: 2, hops: 1 }
            }
            Category::Fewshot => {
                // dense example list; query one mapping among many
                self.filler_run(&mut toks, body, false);
                let n_pairs = 12.min((self.lay.n_entities - 1) / 2);
                let es = self.distinct_entities(2 * n_pairs);
                let mut target = (es[0], es[1]);
                for p in 0..n_pairs {
                    let (i, j) = (es[2 * p], es[2 * p + 1]);
                    let pos = self.plant(&mut toks, lay.pair_tok(i, j), 16);
                    if p == n_pairs / 2 {
                        target = (i, j);
                        let _ = pos;
                    }
                }
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(target.0));
                Task { prompt: toks, expect: vec![lay.value_tok(target.1)], max_new: 2, hops: 1 }
            }
            Category::Synthetic => {
                // passkey: needle in near-uniform PAD-ish noise
                let motif = self.rng.below(self.lay.n_filler());
                for i in 0..body {
                    toks.push(self.lay.filler_tok(motif + (i % 2)));
                }
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                self.plant(&mut toks, lay.pair_tok(i, j), 16);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
            Category::Code => {
                // definition lookup in low-entropy (code-like) filler;
                // needle biased toward the beginning of the file
                self.filler_run(&mut toks, body, true);
                let es = self.distinct_entities(2);
                let (i, j) = (es[0], es[1]);
                let pos = 1 + self.rng.below((toks.len() / 4).max(2));
                toks[pos] = lay.pair_tok(i, j);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(i));
                Task { prompt: toks, expect: vec![lay.value_tok(j)], max_new: 2, hops: 1 }
            }
        }
    }

    /// One AIME-S chain task: `hops` facts scattered in context; the decode
    /// must walk key -> value -> ... -> TERM.
    pub fn aime(&mut self, ctx: usize, hops: usize) -> Task {
        let lay = self.lay;
        let term = lay.term_entity();
        // chain entities: e0 -> e1 -> ... -> e_{hops-1} -> term
        let mut ents = self.distinct_entities(hops);
        ents.push(term);
        let mut toks = vec![VocabLayout::BOS];
        self.filler_run(&mut toks, ctx.saturating_sub(4), false);
        for w in ents.windows(2) {
            self.plant(&mut toks, lay.pair_tok(w[0], w[1]), 16);
        }
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(ents[0]));
        let expect: Vec<u32> = ents[1..].iter().map(|&e| lay.value_tok(e)).collect();
        Task { prompt: toks, expect, max_new: hops * 3 + 8, hops }
    }

    /// Shared-prefix RAG suite (the prefix-cache workload): `n` requests
    /// over one shared ~`shared_ctx`-token document (system prompt +
    /// retrieved corpus with `n` planted facts), each with a unique
    /// ~`unique_ctx`-token tail and a query for its own fact in the
    /// shared document.  All prompts share an identical token prefix of
    /// `shared_ctx` tokens, so with prefix caching enabled only the
    /// first request pays the document prefill.
    pub fn rag_suite(&mut self, n: usize, shared_ctx: usize, unique_ctx: usize) -> Vec<Task> {
        let lay = self.lay;
        assert!(2 * n < lay.n_entities, "too many requests for the entity pool");
        let mut doc = vec![VocabLayout::BOS];
        self.filler_run(&mut doc, shared_ctx.saturating_sub(1), false);
        let es = self.distinct_entities(2 * n);
        let mut facts = Vec::with_capacity(n);
        let mut used = Vec::new();
        // interior positions [1, hi): the retry loop below needs at
        // least n distinct ones or it would never terminate
        let hi = doc.len().saturating_sub(16).max(2);
        assert!(n < hi, "shared document too small for {n} distinct facts");
        for i in 0..n {
            let (a, b) = (es[2 * i], es[2 * i + 1]);
            // plant at a distinct interior position (never clobber an
            // earlier fact, never in the final guard region)
            let mut pos = 1 + self.rng.below(hi - 1);
            while used.contains(&pos) {
                pos = 1 + self.rng.below(hi - 1);
            }
            used.push(pos);
            doc[pos] = lay.pair_tok(a, b);
            facts.push((a, b));
        }
        (0..n)
            .map(|i| {
                let mut toks = doc.clone();
                self.filler_run(&mut toks, unique_ctx, false);
                toks.push(VocabLayout::QUERY);
                toks.push(lay.key_tok(facts[i].0));
                Task {
                    prompt: toks,
                    expect: vec![lay.value_tok(facts[i].1)],
                    max_new: 2,
                    hops: 1,
                }
            })
            .collect()
    }

    /// Calibration prompt (MuSiQue substitute): mixed retrieval content.
    pub fn dev_prompt(&mut self, ctx: usize) -> Vec<u32> {
        let lay = self.lay;
        let mut toks = vec![VocabLayout::BOS];
        self.filler_run(&mut toks, ctx.saturating_sub(4), false);
        for _ in 0..4 {
            let es = self.distinct_entities(2);
            self.plant(&mut toks, lay.pair_tok(es[0], es[1]), 8);
        }
        let e = self.entity();
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(e));
        toks
    }
}

/// Grade a decode against a task: full credit iff the expected sequence is
/// a prefix of the emission; AIME-S additionally requires termination.
pub fn grade(task: &Task, emitted: &[u32]) -> bool {
    if emitted.len() < task.expect.len() {
        return false;
    }
    emitted[..task.expect.len()] == task.expect[..]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SynthSpec;

    fn spec() -> SynthSpec {
        let mut s = SynthSpec::eval_base(1);
        s.cfg.n_layers = 4;
        s.block_starts = vec![1];
        s
    }

    #[test]
    fn prompts_have_requested_shape() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 3);
        for cat in Category::ALL {
            let t = g.longbench(cat, 512);
            assert!(t.prompt.len() >= 500 && t.prompt.len() <= 520, "{cat:?}");
            assert_eq!(t.prompt[0], VocabLayout::BOS);
            assert_eq!(t.prompt[t.prompt.len() - 2], VocabLayout::QUERY);
            assert!(!t.expect.is_empty());
        }
    }

    #[test]
    fn needle_is_present_and_interior() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 4);
        let t = g.longbench(Category::Sqa, 512);
        let lay = g.lay;
        // exactly one pair token, and it maps query key -> expected value
        let key = t.prompt[t.prompt.len() - 1];
        let i = (key - 16) as usize;
        let j = lay.value_entity(t.expect[0]).unwrap();
        let pair = lay.pair_tok(i, j);
        let count = t.prompt.iter().filter(|&&x| x == pair).count();
        assert_eq!(count, 1);
        let pos = t.prompt.iter().position(|&x| x == pair).unwrap();
        assert!(pos > 0 && pos < t.prompt.len() - 16);
    }

    #[test]
    fn aime_chain_is_consistent() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 5);
        let t = g.aime(1024, 6);
        assert_eq!(t.expect.len(), 6);
        assert_eq!(
            g.lay.value_entity(*t.expect.last().unwrap()),
            Some(g.lay.term_entity())
        );
        // each hop's pair token is present
        let key = t.prompt[t.prompt.len() - 1];
        let mut cur = (key - 16) as usize;
        for &v in &t.expect {
            let nxt = g.lay.value_entity(v).unwrap();
            assert!(t.prompt.contains(&g.lay.pair_tok(cur, nxt)), "missing hop {cur}->{nxt}");
            cur = nxt;
        }
    }

    #[test]
    fn grading() {
        let t = Task { prompt: vec![], expect: vec![5, 6], max_new: 4, hops: 2 };
        assert!(grade(&t, &[5, 6]));
        assert!(grade(&t, &[5, 6, 9]));
        assert!(!grade(&t, &[5]));
        assert!(!grade(&t, &[6, 5]));
    }

    #[test]
    fn rag_suite_shares_an_identical_prefix() {
        let s = spec();
        let mut g = WorkloadGen::new(&s, 7);
        let tasks = g.rag_suite(4, 256, 32);
        assert_eq!(tasks.len(), 4);
        let shared = tasks[0].prompt[..256].to_vec();
        for t in &tasks {
            assert_eq!(&t.prompt[..256], &shared[..], "identical shared document");
            assert!(t.prompt.len() >= 256 + 32);
            assert_eq!(t.prompt[t.prompt.len() - 2], VocabLayout::QUERY);
            // the queried fact lives in the shared document
            let key = t.prompt[t.prompt.len() - 1];
            let i = (key - 16) as usize;
            let j = g.lay.value_entity(t.expect[0]).unwrap();
            assert_eq!(shared.iter().filter(|&&x| x == g.lay.pair_tok(i, j)).count(), 1);
        }
        // each request queries a distinct fact
        let keys: std::collections::HashSet<u32> =
            tasks.iter().map(|t| *t.prompt.last().unwrap()).collect();
        assert_eq!(keys.len(), 4);
    }

    #[test]
    fn generator_is_deterministic() {
        let s = spec();
        let a = WorkloadGen::new(&s, 9).longbench(Category::Mqa, 256);
        let b = WorkloadGen::new(&s, 9).longbench(Category::Mqa, 256);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.expect, b.expect);
    }
}
