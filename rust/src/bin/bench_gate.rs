//! CI bench-regression gate: compares the machine-readable bench record
//! (`results/coordinator_bench.json`, written by `make bench`) against
//! the checked-in baseline (`benches/baseline.json`) and exits non-zero
//! if any tracked metric regressed past the baseline's tolerance.
//!
//!   bench_gate [results.json] [baseline.json]
//!
//! Exit codes: 0 all metrics within tolerance, 1 regression, 2 bad input.

use kascade::benchutil::gate_against_baseline;
use kascade::jsonutil::Json;

fn load(path: &str) -> Json {
    // Json::from_file wraps both the I/O and parse failure with the
    // offending path, so one message covers both exit-2 cases.
    match Json::from_file(std::path::Path::new(path)) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench-gate: {e:#}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let mut args = std::env::args().skip(1);
    let results_path = args.next().unwrap_or_else(|| "results/coordinator_bench.json".into());
    let baseline_path = args.next().unwrap_or_else(|| "benches/baseline.json".into());
    let results = load(&results_path);
    let baseline = load(&baseline_path);
    let checks = match gate_against_baseline(&results, &baseline) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench-gate: {e}");
            std::process::exit(2);
        }
    };
    println!("| metric | baseline | floor | current | status |");
    println!("|---|---|---|---|---|");
    for c in &checks {
        println!("{}", c.row());
    }
    let regressed: Vec<_> = checks.iter().filter(|c| !c.ok).collect();
    if regressed.is_empty() {
        println!("bench-gate: all {} metrics within tolerance", checks.len());
    } else {
        for c in &regressed {
            eprintln!(
                "bench-gate: '{}' regressed: {:.4} < floor {:.4} (baseline {:.4})",
                c.metric, c.current, c.floor, c.baseline
            );
        }
        std::process::exit(1);
    }
}
