//! In-repo invariant analyzer (see `docs/analysis.md`).
//!
//!   kascade_analyze [--root <rust-dir>] [--write-api]
//!
//! Scans `<rust-dir>/src` with the four rule families (determinism,
//! hot-path-alloc, api-surface, panic-path).  `--write-api` regenerates
//! `<rust-dir>/analyze/api_surface.json` instead of diffing against it.
//!
//! Exit codes: 0 clean, 1 findings, 2 bad input / I/O error.

use kascade::analyze::{run, Config};
use std::path::PathBuf;

fn main() {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut write_api = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--write-api" => write_api = true,
            "--root" => match args.next() {
                Some(r) => root = PathBuf::from(r),
                None => {
                    eprintln!("kascade-analyze: --root needs a directory");
                    std::process::exit(2);
                }
            },
            other => {
                eprintln!("kascade-analyze: unknown argument '{other}'");
                std::process::exit(2);
            }
        }
    }
    let config = Config::kascade(&root);
    let report = match run(&config, write_api) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("kascade-analyze: {e}");
            std::process::exit(2);
        }
    };
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    for f in &report.findings {
        println!("{}", f.render());
    }
    if write_api {
        println!(
            "kascade-analyze: wrote {} (scanned {} files)",
            config.api_surface_path.as_deref().map(|p| p.display().to_string()).unwrap_or_default(),
            report.files_scanned
        );
    }
    if report.clean() {
        println!(
            "kascade-analyze: clean — {} files, 0 findings, {} warning(s)",
            report.files_scanned,
            report.warnings.len()
        );
    } else {
        eprintln!("kascade-analyze: {} finding(s)", report.findings.len());
        std::process::exit(1);
    }
}
