//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! fixed-sample measurement with mean/std/min, markdown reporting —
//! plus the CI bench-regression gate, which compares the bench run's
//! machine-readable results against a checked-in baseline.

use crate::jsonutil::Json;
use crate::stats::{Timer, Welford};

pub struct BenchResult {
    pub name: String,
    pub mean_us: f64,
    pub std_us: f64,
    pub min_us: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "| {} | {:.1} | {:.1} | {:.1} | {} |",
            self.name, self.mean_us, self.std_us, self.min_us, self.samples
        )
    }
}

/// Measure `f` (one logical operation per call).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..samples {
        let t = Timer::start();
        f();
        w.add(t.us());
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_us: w.mean(),
        std_us: w.std(),
        min_us: w.min(),
        samples,
    };
    println!("{}", r.row());
    r
}

pub fn header() {
    println!("| bench | mean (us) | std | min | n |");
    println!("|---|---|---|---|---|");
}

/// Perf-trajectory artifact (repo-root `BENCH_<pr>.json`): a stable
/// wrapper around one bench run's machine-readable scenario metrics, so
/// the per-PR performance trajectory can be diffed across the repo's
/// history.  The `scenarios` value is the same object the bench writes
/// to `results/coordinator_bench.json` (scenario -> key metrics) — one
/// schema, two consumers (the CI regression gate and the trajectory).
pub fn trajectory(pr: u64, scenarios: Json) -> Json {
    Json::obj(vec![
        ("schema", Json::str("kascade-bench-trajectory-v1")),
        ("pr", Json::num(pr as f64)),
        ("scenarios", scenarios),
    ])
}

/// One metric's comparison against the checked-in baseline.
#[derive(Debug, Clone)]
pub struct GateCheck {
    /// dotted path into the results JSON, e.g. `"prefix_cache.saved_frac"`
    pub metric: String,
    pub baseline: f64,
    /// minimum acceptable value: `baseline * (1 - tolerance)`
    pub floor: f64,
    pub current: f64,
    pub ok: bool,
}

impl GateCheck {
    pub fn row(&self) -> String {
        format!(
            "| {} | {:.4} | {:.4} | {:.4} | {} |",
            self.metric,
            self.baseline,
            self.floor,
            self.current,
            if self.ok { "ok" } else { "REGRESSED" }
        )
    }
}

/// Bench-regression gate: every metric listed in `baseline.metrics`
/// (dotted paths into `results`, higher-is-better) must be at least
/// `baseline * (1 - tolerance)`, with `tolerance` read from the
/// baseline file (default 0.10).  Returns every check so callers can
/// print the full table; `Err` on malformed inputs or a metric missing
/// from the results (a silently skipped metric is a gate that never
/// fires).
pub fn gate_against_baseline(results: &Json, baseline: &Json) -> Result<Vec<GateCheck>, String> {
    let tol = baseline.get("tolerance").and_then(|t| t.as_f64()).unwrap_or(0.10);
    if !(0.0..1.0).contains(&tol) {
        return Err(format!("baseline tolerance {tol} outside [0, 1)"));
    }
    let metrics = baseline
        .get("metrics")
        .and_then(|m| m.as_obj())
        .ok_or("baseline missing 'metrics' object")?;
    if metrics.is_empty() {
        return Err("baseline 'metrics' is empty — the gate would never fire".into());
    }
    let mut out = Vec::new();
    for (path, v) in metrics {
        let base = v
            .as_f64()
            .ok_or_else(|| format!("baseline metric '{path}' is not a number"))?;
        let cur = results
            .path(path)
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("results missing metric '{path}'"))?;
        let floor = base * (1.0 - tol);
        out.push(GateCheck {
            metric: path.clone(),
            baseline: base,
            floor,
            current: cur,
            ok: cur >= floor,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_us >= 0.0);
        assert_eq!(r.samples, 5);
    }

    fn baseline(tol: f64) -> Json {
        Json::obj(vec![
            ("tolerance", Json::num(tol)),
            (
                "metrics",
                Json::obj(vec![
                    ("a.ratio", Json::num(2.0)),
                    ("b.frac", Json::num(0.8)),
                ]),
            ),
        ])
    }

    fn results(ratio: f64, frac: f64) -> Json {
        Json::obj(vec![
            ("a", Json::obj(vec![("ratio", Json::num(ratio))])),
            ("b", Json::obj(vec![("frac", Json::num(frac))])),
        ])
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let checks = gate_against_baseline(&results(1.85, 0.79), &baseline(0.10)).unwrap();
        assert_eq!(checks.len(), 2);
        assert!(checks.iter().all(|c| c.ok), "{checks:?}");
    }

    #[test]
    fn gate_fails_past_tolerance() {
        let checks = gate_against_baseline(&results(1.75, 0.9), &baseline(0.10)).unwrap();
        let bad: Vec<_> = checks.iter().filter(|c| !c.ok).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "a.ratio");
        assert!((bad[0].floor - 1.8).abs() < 1e-9);
    }

    #[test]
    fn gate_errors_on_missing_metric() {
        let partial = Json::obj(vec![("a", Json::obj(vec![("ratio", Json::num(2.0))]))]);
        let err = gate_against_baseline(&partial, &baseline(0.10)).unwrap_err();
        assert!(err.contains("b.frac"), "{err}");
    }

    #[test]
    fn gate_resolves_deep_dotted_paths() {
        let base = Json::obj(vec![(
            "metrics",
            Json::obj(vec![("slo.ttft.p95_ms", Json::num(10.0))]),
        )]);
        let res = Json::parse(r#"{"slo":{"ttft":{"p95_ms":9.5}}}"#).unwrap();
        let checks = gate_against_baseline(&res, &base).unwrap();
        assert_eq!(checks.len(), 1);
        assert!(checks[0].ok);
    }

    #[test]
    fn gate_errors_on_empty_baseline() {
        let empty = Json::obj(vec![("metrics", Json::obj(vec![]))]);
        assert!(gate_against_baseline(&results(2.0, 0.8), &empty).is_err());
    }

    #[test]
    fn trajectory_wraps_scenarios_verbatim() {
        let t = trajectory(5, results(2.0, 0.8));
        assert_eq!(t.get("pr").and_then(|x| x.as_f64()), Some(5.0));
        assert_eq!(
            t.get("schema").and_then(|x| x.as_str()),
            Some("kascade-bench-trajectory-v1")
        );
        let sc = t.get("scenarios").unwrap();
        assert_eq!(sc.get("a").and_then(|a| a.get("ratio")).and_then(|x| x.as_f64()), Some(2.0));
        // round-trips through the serializer the gate reads
        let parsed = Json::parse(&t.to_string()).unwrap();
        assert_eq!(parsed.get("pr").and_then(|x| x.as_f64()), Some(5.0));
    }
}
