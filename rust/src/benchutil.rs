//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! fixed-sample measurement with mean/std/min, markdown reporting.

use crate::stats::{Timer, Welford};

pub struct BenchResult {
    pub name: String,
    pub mean_us: f64,
    pub std_us: f64,
    pub min_us: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "| {} | {:.1} | {:.1} | {:.1} | {} |",
            self.name, self.mean_us, self.std_us, self.min_us, self.samples
        )
    }
}

/// Measure `f` (one logical operation per call).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..samples {
        let t = Timer::start();
        f();
        w.add(t.us());
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_us: w.mean(),
        std_us: w.std(),
        min_us: w.min(),
        samples,
    };
    println!("{}", r.row());
    r
}

pub fn header() {
    println!("| bench | mean (us) | std | min | n |");
    println!("|---|---|---|---|---|");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_us >= 0.0);
        assert_eq!(r.samples, 5);
    }
}
