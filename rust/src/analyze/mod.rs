//! `kascade-analyze`: a dependency-free, token-level static analyzer
//! over the repo's own sources.  It mechanizes the manual "static
//! cross-check" debt sweep with four rule families:
//!
//! * `determinism`    — wall-clock reads, thread-local RNG, and
//!   `HashMap`/`HashSet` iteration inside the attention/kvcache/sparse/
//!   pool/scheduler tick paths (PR 5's bitwise-identical parallel tick
//!   makes iteration order a correctness bug, not a style nit)
//! * `hot-path-alloc` — allocation tokens inside functions marked with
//!   a `// analyze: hot-path` directive, making the zero steady-state
//!   allocation guarantee of `tests/alloc_steady_state.rs` statically
//!   visible
//! * `api-surface`    — `pub fn`/`pub struct` signatures extracted into
//!   the checked-in `analyze/api_surface.json`, plus call-site arity
//!   cross-checks; CI fails on uncommitted drift
//! * `panic-path`     — `unwrap`/`expect`/unguarded caller-index
//!   indexing in the `server.rs`/`coordinator/` request paths and the
//!   `tilestore.rs` spill layer (I/O must surface as `TileStoreError`,
//!   never panic the worker)
//!
//! Audited sites are annotated in source with
//! `// analyze: allow(<rule>) — <reason>`; an annotation without a
//! reason is itself a finding (`allow-grammar`).  See `docs/analysis.md`
//! for the full catalog.

pub mod api_surface;
pub mod items;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

pub const RULE_NAMES: [&str; 5] =
    ["determinism", "hot-path-alloc", "api-surface", "panic-path", "allow-grammar"];

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// non-fatal notes (e.g. an allow annotation that no finding used)
    pub warnings: Vec<String>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// What to analyze and how.  Scope entries are paths relative to
/// `root`: entries ending in `/` match the whole subtree, anything else
/// matches that file exactly.
pub struct Config {
    /// directory scanned recursively for `.rs` files
    pub root: PathBuf,
    pub determinism_scope: Vec<String>,
    pub panic_scope: Vec<String>,
    /// repo-wide floor on `// analyze: hot-path` markers, so the
    /// allocation rule cannot be silenced by deleting its markers
    pub min_hot_path_markers: usize,
    /// committed API-surface JSON to diff against (`None` = skip drift)
    pub api_surface_path: Option<PathBuf>,
}

impl Config {
    /// The repo's own configuration: `root` is `rust/src`, the surface
    /// file lives at `rust/analyze/api_surface.json`.
    pub fn kascade(rust_dir: &Path) -> Config {
        Config {
            root: rust_dir.join("src"),
            determinism_scope: vec![
                "attention.rs".into(),
                "sparse/".into(),
                "pool.rs".into(),
                "server.rs".into(),
                "coordinator/scheduler.rs".into(),
                "coordinator/sequence.rs".into(),
                "model/forward.rs".into(),
                // the SIMD dispatch layer: every level must produce
                // bitwise-identical results, so ambient nondeterminism
                // (clocks, hash iteration) is as much a bug here as in
                // the engine tick
                "simd.rs".into(),
                // the gateway routes deterministically given registry
                // state; its few legitimate wall-clock sites (admin
                // drain deadline) carry annotated allows with reasons
                "gateway/".into(),
            ],
            panic_scope: vec![
                "server.rs".into(),
                "coordinator/".into(),
                // the KV spill layer: tier I/O must come back as typed
                // TileStoreError values, never unwrap/expect a request away
                "tilestore.rs".into(),
                // the network front end: peer I/O must surface as typed
                // HttpError values — a bad peer fails its connection,
                // never the process
                "gateway/".into(),
            ],
            // PR 10 marked every simd.rs dispatcher (14) on top of the
            // forward/attention kernels — the floor tracks just under
            // the real count so marker deletion still trips the rule
            min_hot_path_markers: 16,
            api_surface_path: Some(rust_dir.join("analyze/api_surface.json")),
        }
    }

    /// Everything in scope, no surface file, no marker floor — the
    /// fixture-corpus configuration used by `tests/analyze.rs`.
    pub fn bare(root: PathBuf) -> Config {
        Config {
            root,
            determinism_scope: vec!["".into()],
            panic_scope: vec!["".into()],
            min_hot_path_markers: 0,
            api_surface_path: None,
        }
    }
}

pub fn in_scope(rel: &str, scope: &[String]) -> bool {
    scope.iter().any(|s| {
        if s.is_empty() {
            true
        } else if s.ends_with('/') {
            rel.starts_with(s.as_str())
        } else {
            rel == s
        }
    })
}

/// A parsed `// analyze: allow(<rule>) — <reason>` annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub reason: String,
    /// the source line the annotation suppresses (same line for a
    /// trailing comment, the next code-bearing line for a full-line one)
    pub target: usize,
}

/// One fully-scanned source file, shared by every rule pass.
pub struct FileCtx {
    pub rel: String,
    pub code: String,
    pub tests: Vec<(usize, usize)>,
    pub fns: Vec<items::FnItem>,
    pub allows: Vec<Allow>,
    /// lines carrying a `// analyze: hot-path` marker
    pub hot_lines: Vec<usize>,
    /// malformed directives: (line, what's wrong)
    pub malformed: Vec<(usize, String)>,
}

impl FileCtx {
    pub fn parse(rel: String, src: &str) -> FileCtx {
        let stripped = lexer::strip(src);
        let code = stripped.code;
        let tests = items::test_spans(&code);
        let blocks = items::assoc_blocks(&code);
        let fns = items::fn_items(&code, &blocks);
        let line_has_code: Vec<bool> =
            code.lines().map(|l| !l.trim().is_empty()).collect();
        let mut allows = Vec::new();
        let mut hot_lines = Vec::new();
        let mut malformed = Vec::new();
        for c in &stripped.comments {
            let body = c.text.trim_start_matches('/').trim_start_matches('!').trim();
            let Some(rest) = body.strip_prefix("analyze:") else { continue };
            let rest = rest.trim();
            if rest == "hot-path" {
                hot_lines.push(c.line);
            } else if let Some(tail) = rest.strip_prefix("allow(") {
                match parse_allow(tail) {
                    Ok((rule, reason)) => {
                        let own_line_has_code = line_has_code
                            .get(c.line - 1)
                            .is_some_and(|&has| has);
                        let target = if own_line_has_code {
                            c.line
                        } else {
                            next_code_line(&line_has_code, c.line)
                        };
                        allows.push(Allow { line: c.line, rule, reason, target });
                    }
                    Err(why) => malformed.push((c.line, why)),
                }
            } else {
                malformed.push((c.line, format!("unrecognized directive '{rest}'")));
            }
        }
        FileCtx { rel, code, tests, fns, allows, hot_lines, malformed }
    }

    pub fn is_test_pos(&self, pos: usize) -> bool {
        items::in_spans(&self.tests, pos)
    }
}

/// Parse `<rule>) — <reason>` (the part after `allow(`).
fn parse_allow(tail: &str) -> Result<(String, String), String> {
    let Some(close) = tail.find(')') else {
        return Err("unterminated allow(...)".into());
    };
    let rule = tail[..close].trim().to_string();
    if !RULE_NAMES.contains(&rule.as_str()) {
        return Err(format!("unknown rule '{rule}' in allow(...)"));
    }
    let after = tail[close + 1..].trim_start();
    let reason = after
        .strip_prefix('—')
        .or_else(|| after.strip_prefix('-'))
        .map(str::trim)
        .unwrap_or("");
    if reason.is_empty() {
        return Err(format!("allow({rule}) carries no reason — write `allow({rule}) — <why>`"));
    }
    Ok((rule, reason.to_string()))
}

/// First code-bearing line after `line` (1-indexed), skipping blank and
/// comment-only lines, bounded so a stray annotation cannot suppress a
/// finding pages away.
fn next_code_line(line_has_code: &[bool], line: usize) -> usize {
    for l in line + 1..(line + 5).min(line_has_code.len() + 1) {
        if line_has_code[l - 1] {
            return l;
        }
    }
    line + 1
}

/// Recursively collect `.rs` files under `root`, as (rel, contents),
/// sorted by path for deterministic reports.
fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push((rel, std::fs::read_to_string(&path)?));
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Run every rule over `config.root`, apply allow annotations, and
/// return the report.  `write_api` regenerates the surface file instead
/// of diffing against it.
pub fn run(config: &Config, write_api: bool) -> std::io::Result<Report> {
    let sources = collect_sources(&config.root)?;
    let files: Vec<FileCtx> =
        sources.into_iter().map(|(rel, src)| FileCtx::parse(rel, &src)).collect();

    let mut raw: Vec<Finding> = Vec::new();
    for f in &files {
        if in_scope(&f.rel, &config.determinism_scope) {
            raw.extend(rules::determinism(f));
        }
        raw.extend(rules::hot_path_alloc(f));
        if in_scope(&f.rel, &config.panic_scope) {
            raw.extend(rules::panic_path(f));
        }
    }
    raw.extend(api_surface::check(&files, config, write_api)?);

    let mut report = Report { files_scanned: files.len(), ..Report::default() };

    // allow application: a finding is suppressed by a matching-rule
    // annotation targeting its line; unused annotations are warnings
    for f in &files {
        let mut used = vec![false; f.allows.len()];
        raw.retain(|fd| {
            if fd.file != f.rel {
                return true;
            }
            for (i, a) in f.allows.iter().enumerate() {
                if a.rule == fd.rule && (a.target == fd.line || a.line == fd.line) {
                    used[i] = true;
                    return false;
                }
            }
            true
        });
        for (i, a) in f.allows.iter().enumerate() {
            if !used[i] {
                report.warnings.push(format!(
                    "{}:{}: allow({}) matched no finding — stale annotation?",
                    f.rel, a.line, a.rule
                ));
            }
        }
        for (line, why) in &f.malformed {
            raw.push(Finding {
                rule: "allow-grammar",
                file: f.rel.clone(),
                line: *line,
                msg: why.clone(),
            });
        }
    }

    // marker floor: deleting hot-path markers must not pass silently
    let markers: usize = files.iter().map(|f| f.hot_lines.len()).sum();
    if markers < config.min_hot_path_markers {
        raw.push(Finding {
            rule: "hot-path-alloc",
            file: String::new(),
            line: 0,
            msg: format!(
                "only {markers} `analyze: hot-path` markers found (floor {}) — \
                 markers must not be removed to silence the rule",
                config.min_hot_path_markers
            ),
        });
    }

    raw.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report.findings = raw;
    Ok(report)
}
