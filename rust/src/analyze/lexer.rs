//! Token-level "code view" of a Rust source file: same byte length as
//! the original, with the contents of comments, string literals, and
//! char literals blanked to spaces (newlines preserved).  Rule passes
//! run over this view so `"Instant::now"` inside a string or a comment
//! can never trip a lint, while byte offsets and line numbers still map
//! 1:1 onto the original file.
//!
//! Comments are additionally collected verbatim (with their line
//! numbers) because the `// analyze:` directive grammar lives in them.

/// One comment's text (`//` line or `/* */` block, delimiters included)
/// plus the 1-indexed line it starts on.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Output of [`strip`].
pub struct Stripped {
    /// Same byte length as the input; comment/string/char contents are
    /// spaces, newlines are kept so line numbers line up.
    pub code: String,
    pub comments: Vec<Comment>,
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Blank every byte of `out[a..b]` except newlines.
fn blank(out: &mut [u8], a: usize, b: usize) {
    for byte in out[a..b].iter_mut() {
        if *byte != b'\n' {
            *byte = b' ';
        }
    }
}

/// Scan past a `\`-escape inside a string/char literal starting at the
/// backslash; returns the index just past the escape.
fn skip_escape(b: &[u8], i: usize) -> usize {
    // i points at the backslash
    if i + 1 >= b.len() {
        return i + 1;
    }
    match b[i + 1] {
        b'u' => {
            // \u{...}
            let mut j = i + 2;
            if b.get(j) == Some(&b'{') {
                while j < b.len() && b[j] != b'}' {
                    j += 1;
                }
                j + 1
            } else {
                j
            }
        }
        _ => i + 2,
    }
}

/// Build the code view.  Handles line comments, nested block comments,
/// strings, raw strings (`r"`, `r#"`, `br##"`, ...), byte strings, and
/// the char-literal-vs-lifetime ambiguity.
pub fn strip(src: &str) -> Stripped {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // comments -----------------------------------------------------
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            comments.push(Comment { line, text: src[start..i].to_string() });
            blank(&mut out, start, i);
            continue;
        }
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: src[start..i].to_string() });
            blank(&mut out, start, i);
            continue;
        }
        // raw / byte strings -------------------------------------------
        let prev_ident = i > 0 && is_ident_byte(b[i - 1]);
        if !prev_ident && (c == b'r' || c == b'b') {
            // r"..."  r#"..."#  br"..."  b"..."  (any # count)
            let mut j = i + 1;
            if c == b'b' && j < b.len() && b[j] == b'r' {
                j += 1;
            }
            let raw = j > i + 1 || c == b'r';
            if raw {
                let mut hashes = 0usize;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    // scan for closing quote + `hashes` #s
                    let mut k = j + 1;
                    'raw: while k < b.len() {
                        if b[k] == b'"' {
                            let mut h = 0usize;
                            while k + 1 + h < b.len() && h < hashes && b[k + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                k += 1 + hashes;
                                break 'raw;
                            }
                        }
                        k += 1;
                    }
                    for idx in i..k.min(b.len()) {
                        if b[idx] == b'\n' {
                            line += 1;
                        }
                    }
                    blank(&mut out, i, k.min(b.len()));
                    i = k.min(b.len());
                    continue;
                }
            }
            if c == b'b' && i + 1 < b.len() && (b[i + 1] == b'"' || b[i + 1] == b'\'') {
                // fall through to the string/char scanners below, one
                // byte in, after blanking the prefix
                out[i] = b' ';
                i += 1;
                continue;
            }
        }
        // plain strings ------------------------------------------------
        if c == b'"' {
            let start = i;
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' {
                    i = skip_escape(b, i);
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            blank(&mut out, start, i);
            continue;
        }
        // char literal vs lifetime -------------------------------------
        if c == b'\'' {
            let mut j = i + 1;
            let mut is_char = false;
            if j < b.len() {
                if b[j] == b'\\' {
                    j = skip_escape(b, j);
                    is_char = j < b.len() && b[j] == b'\'';
                    if is_char {
                        j += 1;
                    }
                } else if b[j] < 0x80 {
                    // 'x' only when a closing quote follows exactly one char
                    if j + 1 < b.len() && b[j + 1] == b'\'' {
                        is_char = true;
                        j += 2;
                    }
                } else {
                    // multibyte char literal
                    let ch_len = src[j..].chars().next().map(|ch| ch.len_utf8()).unwrap_or(1);
                    if j + ch_len < b.len() && b[j + ch_len] == b'\'' {
                        is_char = true;
                        j += ch_len + 1;
                    }
                }
            }
            if is_char {
                blank(&mut out, i, j);
                i = j;
            } else {
                // a lifetime — leave it (harmless to every rule)
                i += 1;
            }
            continue;
        }
        i += 1;
    }
    // the blanking above only wrote ASCII spaces over existing bytes, so
    // the buffer can only be invalid UTF-8 if we clipped a multibyte
    // char; blanked regions replace whole chars, so this cannot fail
    let code = String::from_utf8_lossy(&out).into_owned();
    Stripped { code, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_comments_and_strings_preserving_length_and_lines() {
        let src = "let a = \"Instant::now()\"; // Instant::now()\nlet b = 1;\n";
        let s = strip(src);
        assert_eq!(s.code.len(), src.len());
        assert!(!s.code.contains("Instant"));
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
        assert_eq!(s.comments.len(), 1);
        assert_eq!(s.comments[0].line, 1);
        assert!(s.comments[0].text.contains("Instant::now"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* outer /* inner */ still */ fn f() {}\nlet r = r#\"a \" b\"#;\n";
        let s = strip(src);
        assert!(!s.code.contains("outer"));
        assert!(!s.code.contains("still"));
        assert!(s.code.contains("fn f()"));
        assert!(!s.code.contains("a \" b"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '\\''; let d = 'x'; '§' }";
        let s = strip(src);
        assert_eq!(s.code.len(), src.len());
        assert!(s.code.contains("'a str"), "lifetimes kept: {}", s.code);
        assert!(!s.code.contains("'x'"), "char literal blanked: {}", s.code);
        assert!(!s.code.contains('§'), "multibyte char blanked");
    }

    #[test]
    fn byte_strings_and_escapes() {
        let src = "let a = b\"bytes\"; let b = \"esc \\\" quote\"; let u = '\\u{1F600}';";
        let s = strip(src);
        assert!(!s.code.contains("bytes"));
        assert!(!s.code.contains("quote"));
        assert!(!s.code.contains("1F600"));
        assert!(s.code.contains("let b ="));
    }
}