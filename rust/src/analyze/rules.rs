//! The determinism, hot-path-allocation, and panic-path rule passes.
//! Each takes one scanned [`FileCtx`] and returns raw findings; allow
//! annotations are applied by the caller ([`crate::analyze::run`]).

use super::items::{find_word, line_of};
use super::{FileCtx, Finding};

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// The identifier ending just before byte `idx` (trailing spaces
/// skipped), e.g. `ident_before("let seqs =", 9)` -> `seqs`.
fn ident_before(code: &str, idx: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut end = idx;
    while end > 0 && (b[end - 1] == b' ' || b[end - 1] == b'\n') {
        end -= 1;
    }
    let mut start = end;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    if start == end {
        None
    } else {
        Some(code[start..end].to_string())
    }
}

// ---------------------------------------------------------------------
// determinism
// ---------------------------------------------------------------------

/// Tokens that read ambient nondeterminism.  Wall-clock reads are only
/// legitimate at audited metrics/deadline sites (annotated in source).
const TIME_RNG_TOKENS: [&str; 5] =
    ["Instant::now", "SystemTime", "thread_rng", "from_entropy", "random_state"];

const MAP_ITER_SUFFIXES: [&str; 8] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".drain(",
    ".retain(",
];

/// Identifiers in this file declared (or initialized) as
/// `HashMap`/`HashSet`: `name: HashMap<..>` fields/params and
/// `let [mut] name = HashMap::new()` bindings.
fn hash_container_names(code: &str) -> Vec<String> {
    let b = code.as_bytes();
    let mut names = Vec::new();
    for ty in ["HashMap", "HashSet"] {
        let mut at = 0usize;
        while let Some(pos) = find_word(code, ty, at) {
            at = pos + ty.len();
            let mut k = pos;
            while k > 0 && b[k - 1] == b' ' {
                k -= 1;
            }
            let name = if k > 0 && b[k - 1] == b':' && (k < 2 || b[k - 2] != b':') {
                // `name: HashMap<..>` — field or parameter
                ident_before(code, k - 1)
            } else if k > 0 && b[k - 1] == b'=' {
                // `let [mut] name = HashMap::new()`
                ident_before(code, k - 1)
            } else {
                None
            };
            if let Some(n) = name {
                if n != "mut" && !names.contains(&n) {
                    names.push(n);
                }
            }
        }
    }
    names
}

/// Is the word occurrence at `pos` the sequence of a `for .. in [&
/// mut][self.]name` loop header?
fn is_for_in_target(code: &str, pos: usize) -> bool {
    let b = code.as_bytes();
    let mut k = pos;
    if k >= 5 && &code[k - 5..k] == "self." {
        k -= 5;
    }
    while k > 0 && (b[k - 1] == b'&' || b[k - 1] == b' ') {
        k -= 1;
    }
    if k >= 4 && &code[k - 4..k] == "mut " {
        k -= 4;
    }
    while k > 0 && (b[k - 1] == b'&' || b[k - 1] == b' ') {
        k -= 1;
    }
    if k < 2 || &code[k - 2..k] != "in" {
        return false;
    }
    if k >= 3 && is_ident_byte(b[k - 3]) {
        return false;
    }
    // require a `for` earlier on the same line
    let line_start = code[..k].rfind('\n').map(|p| p + 1).unwrap_or(0);
    find_word(&code[line_start..k], "for", 0).is_some()
}

pub fn determinism(f: &FileCtx) -> Vec<Finding> {
    let mut out = Vec::new();
    for tok in TIME_RNG_TOKENS {
        let mut at = 0usize;
        while let Some(pos) = find_word(&f.code, tok, at) {
            at = pos + tok.len();
            if f.is_test_pos(pos) {
                continue;
            }
            out.push(Finding {
                rule: "determinism",
                file: f.rel.clone(),
                line: line_of(&f.code, pos),
                msg: format!(
                    "`{tok}` in a determinism-scoped path — engine ticks must be \
                     replayable; annotate audited metrics/deadline sites"
                ),
            });
        }
    }
    for name in hash_container_names(&f.code) {
        let mut at = 0usize;
        while let Some(pos) = find_word(&f.code, &name, at) {
            at = pos + name.len();
            if f.is_test_pos(pos) {
                continue;
            }
            // skip whitespace so a rustfmt-broken chain
            // (`self.seqs\n    .iter()`) cannot evade the rule
            let rest = f.code[pos + name.len()..].trim_start();
            let iterated = MAP_ITER_SUFFIXES.iter().any(|s| rest.starts_with(s))
                || is_for_in_target(&f.code, pos);
            if iterated {
                out.push(Finding {
                    rule: "determinism",
                    file: f.rel.clone(),
                    line: line_of(&f.code, pos),
                    msg: format!(
                        "iteration over hash container `{name}` — order is \
                         nondeterministic; sort keys or use an ordered container"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// hot-path-alloc
// ---------------------------------------------------------------------

const ALLOC_TOKENS: [&str; 11] = [
    "Vec::new",
    "String::new",
    "Box::new",
    "vec!",
    "format!",
    ".push(",
    ".to_vec()",
    ".clone()",
    ".collect()",
    ".collect::",
    ".to_string()",
];

pub fn hot_path_alloc(f: &FileCtx) -> Vec<Finding> {
    let mut out = Vec::new();
    for &marker_line in &f.hot_lines {
        // the marked fn is the first one starting within a few lines
        // below the marker (attributes may sit between)
        let marked = f
            .fns
            .iter()
            .filter(|fun| fun.line > marker_line && fun.line <= marker_line + 6)
            .min_by_key(|fun| fun.line);
        let Some(fun) = marked else {
            out.push(Finding {
                rule: "hot-path-alloc",
                file: f.rel.clone(),
                line: marker_line,
                msg: "`analyze: hot-path` marker is not followed by a fn".into(),
            });
            continue;
        };
        let Some((body_start, body_end)) = fun.body else {
            continue;
        };
        let body = &f.code[body_start..body_end];
        for tok in ALLOC_TOKENS {
            let mut at = 0usize;
            while let Some(rel_pos) = body[at..].find(tok) {
                let pos = body_start + at + rel_pos;
                at += rel_pos + tok.len();
                if f.is_test_pos(pos) {
                    continue;
                }
                out.push(Finding {
                    rule: "hot-path-alloc",
                    file: f.rel.clone(),
                    line: line_of(&f.code, pos),
                    msg: format!(
                        "`{tok}` inside hot-path fn `{}` — the decode loop must not \
                         allocate per token (tests/alloc_steady_state.rs)",
                        fun.name
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// panic-path
// ---------------------------------------------------------------------

/// Does the fn body contain a bounds guard mentioning `param`: a
/// comparison (`p <`, `>= p`, ...), a checked `.get(p)`, or a clamp?
fn param_guarded(body: &str, param: &str) -> bool {
    let mut at = 0usize;
    while let Some(pos) = find_word(body, param, at) {
        at = pos + param.len();
        let before = body[..pos].trim_end();
        let after = body[pos + param.len()..].trim_start();
        if after.starts_with('<') || after.starts_with('>') {
            return true;
        }
        let cmp_before = before.ends_with('<')
            || before.ends_with('>')
            || before.ends_with("<=")
            || before.ends_with(">=");
        if cmp_before {
            return true;
        }
        if before.ends_with(".get(") || before.ends_with(".get_mut(") {
            return true;
        }
        if after.starts_with(".min(") || after.starts_with(".clamp(") {
            return true;
        }
    }
    false
}

pub fn panic_path(f: &FileCtx) -> Vec<Finding> {
    let mut out = Vec::new();
    for tok in [".unwrap()", ".expect("] {
        let mut at = 0usize;
        while let Some(rel_pos) = f.code[at..].find(tok) {
            let pos = at + rel_pos;
            at = pos + tok.len();
            if f.is_test_pos(pos) {
                continue;
            }
            let what = tok.trim_end_matches(['(', ')']);
            out.push(Finding {
                rule: "panic-path",
                file: f.rel.clone(),
                line: line_of(&f.code, pos),
                msg: format!(
                    "`{what}()` on a request path — a poisoned request must fail the \
                     request, not the worker; handle or annotate the audited invariant"
                ),
            });
        }
    }
    // caller-provided index used without a bounds guard
    for fun in &f.fns {
        let Some((body_start, body_end)) = fun.body else { continue };
        if f.is_test_pos(fun.pos) {
            continue;
        }
        let body = &f.code[body_start..body_end];
        for param in &fun.params {
            if param.is_empty() || !param.bytes().all(is_ident_byte) {
                continue;
            }
            let mut at = 0usize;
            let mut indexed_at = None;
            while let Some(pos) = find_word(body, param, at) {
                at = pos + param.len();
                let before_ok = pos > 0 && body.as_bytes()[pos - 1] == b'[';
                let rest = &body[pos + param.len()..];
                let after_ok = rest.starts_with(']') || rest.starts_with(" as ");
                if before_ok && after_ok {
                    indexed_at = Some(body_start + pos);
                    break;
                }
            }
            if let Some(pos) = indexed_at {
                if !param_guarded(body, param) {
                    out.push(Finding {
                        rule: "panic-path",
                        file: f.rel.clone(),
                        line: line_of(&f.code, pos),
                        msg: format!(
                            "`{}` indexes with caller-provided `{param}` and no bounds \
                             guard — out-of-range input panics the worker",
                            fun.name
                        ),
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(src: &str) -> FileCtx {
        FileCtx::parse("test.rs".into(), src)
    }

    #[test]
    fn determinism_flags_clock_and_map_iteration() {
        let src = "fn tick(seqs: HashMap<u64, u32>) {\n\
                   let t = Instant::now();\n\
                   for (k, v) in &seqs {}\n\
                   let ks = seqs.keys();\n\
                   }\n";
        let fs = determinism(&ctx(src));
        assert_eq!(fs.iter().filter(|f| f.msg.contains("Instant::now")).count(), 1);
        assert_eq!(fs.iter().filter(|f| f.msg.contains("`seqs`")).count(), 2);
    }

    #[test]
    fn determinism_ignores_tests_and_ordered_access() {
        let src = "fn ok(seqs: HashMap<u64, u32>) { let v = seqs.get(&1); }\n\
                   #[cfg(test)]\nmod tests {\n fn t() { let x = Instant::now(); }\n}\n";
        assert!(determinism(&ctx(src)).is_empty());
    }

    #[test]
    fn hot_path_flags_alloc_tokens_only_in_marked_fns() {
        let src = "// analyze: hot-path\n\
                   fn kernel(out: &mut Vec<f32>) { out.push(1.0); }\n\
                   fn setup(out: &mut Vec<f32>) { out.push(1.0); }\n";
        let fs = hot_path_alloc(&ctx(src));
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("kernel"));
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn panic_path_flags_unwrap_and_unguarded_param_index() {
        let src = "pub fn stop(&mut self, w: usize) { self.txs[w].take().unwrap(); }\n\
                   pub fn ok(&mut self, w: usize) {\n\
                   if w < self.txs.len() { let _ = &self.txs[w]; }\n\
                   }\n";
        let fs = panic_path(&ctx(src));
        assert_eq!(fs.iter().filter(|f| f.msg.contains(".unwrap()")).count(), 1);
        assert_eq!(fs.iter().filter(|f| f.msg.contains("bounds")).count(), 1, "{fs:?}");
        assert!(fs.iter().all(|f| !f.msg.contains("`ok`")));
    }
}
