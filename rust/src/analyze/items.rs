//! Structural scanning over the blanked code view: function spans with
//! parameter lists, `impl`/`trait` block association, `#[cfg(test)]` /
//! `#[test]` spans, and brace matching.  Deliberately token-level — no
//! full parser — but strings and comments are already blanked, so brace
//! and paren matching cannot be confused by literals.

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// 1-indexed line number of byte offset `pos`.
pub fn line_of(code: &str, pos: usize) -> usize {
    1 + code.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count()
}

/// Find the matching close delimiter for the open delimiter at `open`
/// (which must be `(`, `[`, or `{`).  Returns the index of the closer,
/// or `None` if the file ends first.  Only the matching delimiter kind
/// is tracked against its partner; all three kinds nest.
pub fn match_delim(code: &str, open: usize) -> Option<usize> {
    let b = code.as_bytes();
    let (o, c) = match b[open] {
        b'(' => (b'(', b')'),
        b'[' => (b'[', b']'),
        b'{' => (b'{', b'}'),
        _ => return None,
    };
    let mut depth = 0usize;
    for (i, &byte) in b.iter().enumerate().skip(open) {
        if byte == o {
            depth += 1;
        } else if byte == c {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// Next occurrence of `needle` as a whole word (not embedded in a wider
/// identifier) at or after `from`.
pub fn find_word(code: &str, needle: &str, from: usize) -> Option<usize> {
    let b = code.as_bytes();
    let mut at = from;
    while let Some(rel) = code[at..].find(needle) {
        let pos = at + rel;
        let before_ok = pos == 0 || !is_ident_byte(b[pos - 1]);
        let end = pos + needle.len();
        let after_ok = end >= b.len() || !is_ident_byte(b[end]);
        if before_ok && after_ok {
            return Some(pos);
        }
        at = pos + 1;
    }
    None
}

/// Split `text` on top-level commas (tracking `()`, `[]`, `{}`, `<>`
/// nesting).  `<>` tracking is heuristic (comparison operators inside
/// argument lists can skew it) but parameter lists never contain bare
/// comparisons, which is the only place this is used with angles.
pub fn split_top_commas(text: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut angle = 0i32;
    let mut cur = String::new();
    for ch in text.chars() {
        match ch {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            '<' => angle += 1,
            '>' => angle = (angle - 1).max(0),
            ',' if depth == 0 && angle == 0 => {
                parts.push(cur.trim().to_string());
                cur.clear();
                continue;
            }
            _ => {}
        }
        cur.push(ch);
    }
    let last = cur.trim().to_string();
    if !last.is_empty() {
        parts.push(last);
    }
    parts
}

/// A `fn` item found in the code view.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// byte offset of the `fn` keyword in the code view
    pub pos: usize,
    pub line: usize,
    pub is_pub: bool,
    pub has_self: bool,
    /// non-`self` parameter names (pattern text before the `:`)
    pub params: Vec<String>,
    /// byte span of the body in the code view (`{`..=`}`), `None` for
    /// trait-method declarations without a default body
    pub body: Option<(usize, usize)>,
    /// enclosing `impl Type` / `trait Type` name, if any
    pub assoc: Option<String>,
}

/// An `impl`/`trait` block span with the associated type name.
#[derive(Debug, Clone)]
pub struct AssocBlock {
    pub name: String,
    pub span: (usize, usize),
}

/// Last path segment of a type expression, generics stripped:
/// `attention::KvCache<'a, T>` -> `KvCache`.
fn type_name(text: &str) -> String {
    let no_gen = match text.find('<') {
        Some(i) => &text[..i],
        None => text,
    };
    let seg = no_gen.rsplit("::").next().unwrap_or(no_gen);
    seg.trim().trim_start_matches('&').trim().to_string()
}

/// Scan `impl` and `trait` block spans.
pub fn assoc_blocks(code: &str) -> Vec<AssocBlock> {
    let mut out = Vec::new();
    for kw in ["impl", "trait"] {
        let mut at = 0usize;
        while let Some(pos) = find_word(code, kw, at) {
            at = pos + kw.len();
            let Some(open_rel) = code[at..].find('{') else { continue };
            let open = at + open_rel;
            let header = &code[at..open];
            // `impl<T> Foo for Bar<T>` — the implemented-on type is after
            // `for`; otherwise the whole header is the type
            // `trait Policy: Send` — the name stops at the supertrait
            // list (`impl` headers keep their `::` paths intact)
            let header = match kw {
                "trait" => header.split(':').next().unwrap_or(header),
                _ => header,
            };
            let ty = match find_word(header, "for", 0) {
                Some(f) if kw == "impl" => type_name(&header[f + 3..]),
                _ => type_name(header),
            };
            if ty.is_empty() || !ty.bytes().all(is_ident_byte) {
                continue;
            }
            let Some(close) = match_delim(code, open) else { continue };
            out.push(AssocBlock { name: ty, span: (open, close) });
            // do NOT skip past the block: trait fns with default bodies
            // live inside and must still be found by the fn scan below
        }
    }
    out
}

/// Byte spans of test-only code: the item following `#[cfg(test)]` or
/// `#[test]` (scan to its first `{`, then brace-match).
pub fn test_spans(code: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for marker in ["#[cfg(test)]", "#[test]"] {
        let mut at = 0usize;
        while let Some(rel) = code[at..].find(marker) {
            let pos = at + rel;
            at = pos + marker.len();
            if let Some(open_rel) = code[at..].find('{') {
                let open = at + open_rel;
                if let Some(close) = match_delim(code, open) {
                    spans.push((pos, close));
                }
            }
        }
    }
    spans
}

pub fn in_spans(spans: &[(usize, usize)], pos: usize) -> bool {
    spans.iter().any(|&(a, b)| pos >= a && pos <= b)
}

/// Scan every `fn` item.  `blocks` associates methods with their
/// `impl`/`trait` type.
pub fn fn_items(code: &str, blocks: &[AssocBlock]) -> Vec<FnItem> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut at = 0usize;
    while let Some(pos) = find_word(code, "fn", at) {
        at = pos + 2;
        // name
        let mut j = at;
        while j < b.len() && (b[j] == b' ' || b[j] == b'\n') {
            j += 1;
        }
        let name_start = j;
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        if j == name_start {
            continue;
        }
        let name = code[name_start..j].to_string();
        // generics between name and params
        let mut k = j;
        while k < b.len() && (b[k] == b' ' || b[k] == b'\n') {
            k += 1;
        }
        if k < b.len() && b[k] == b'<' {
            let mut depth = 0i32;
            while k < b.len() {
                match b[k] {
                    b'<' => depth += 1,
                    b'>' => {
                        depth -= 1;
                        if depth == 0 {
                            k += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
        }
        while k < b.len() && (b[k] == b' ' || b[k] == b'\n') {
            k += 1;
        }
        if k >= b.len() || b[k] != b'(' {
            continue;
        }
        let Some(close) = match_delim(code, k) else { continue };
        let mut has_self = false;
        let mut params = Vec::new();
        for part in split_top_commas(&code[k + 1..close]) {
            let pat = part.split(':').next().unwrap_or("").trim();
            let pat = pat.trim_start_matches('&').trim();
            let pat = pat.strip_prefix("mut ").unwrap_or(pat).trim();
            if pat == "self" || pat.ends_with(" self") {
                has_self = true;
            } else if !pat.is_empty() {
                params.push(pat.to_string());
            }
        }
        // body: first `{` before any `;` (a `;` first means a bodyless
        // trait-method declaration)
        let mut m = close + 1;
        let mut body = None;
        while m < b.len() {
            if b[m] == b';' {
                break;
            }
            if b[m] == b'{' {
                if let Some(end) = match_delim(code, m) {
                    body = Some((m, end));
                }
                break;
            }
            m += 1;
        }
        // visibility: look back from `fn` for `pub` on the same item
        // (allowing `pub(crate) unsafe const` prefixes)
        let lead_start = pos.saturating_sub(48);
        let lead = &code[lead_start..pos];
        let tail = lead.rsplit(['\n', ';', '}', '{']).next().unwrap_or(lead);
        // plain `pub` only — `pub(crate)`/`pub(super)` are not public API
        let is_pub = match find_word(tail, "pub", 0) {
            Some(p) => !tail[p + 3..].trim_start().starts_with('('),
            None => false,
        };
        // innermost enclosing impl/trait block
        let assoc = blocks
            .iter()
            .filter(|blk| pos > blk.span.0 && pos < blk.span.1)
            .min_by_key(|blk| blk.span.1 - blk.span.0)
            .map(|blk| blk.name.clone());
        out.push(FnItem {
            name,
            pos,
            line: line_of(code, pos),
            is_pub,
            has_self,
            params,
            body,
            assoc,
        });
        at = match body {
            Some((open, _)) => open + 1,
            None => close + 1,
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::strip;

    const SRC: &str = r#"
pub struct Foo;

impl Foo {
    pub fn method(&self, a: usize, b: &[f32]) -> usize {
        a + b.len()
    }
    fn private_helper(x: u32) -> u32 { x }
}

pub trait Policy {
    fn decode(&mut self, q: &[f32]) -> usize;
    fn name(&self) -> String {
        String::new()
    }
}

pub fn free_fn<T: Clone>(items: &mut Vec<T>, n: usize) {}

#[cfg(test)]
mod tests {
    fn test_only_helper(z: usize) -> usize { z }
}
"#;

    #[test]
    fn finds_fns_with_assoc_params_and_visibility() {
        let code = strip(SRC).code;
        let blocks = assoc_blocks(&code);
        let fns = fn_items(&code, &blocks);
        let method = fns.iter().find(|f| f.name == "method").unwrap();
        assert!(method.is_pub && method.has_self);
        assert_eq!(method.params, vec!["a", "b"]);
        assert_eq!(method.assoc.as_deref(), Some("Foo"));
        let helper = fns.iter().find(|f| f.name == "private_helper").unwrap();
        assert!(!helper.is_pub && !helper.has_self);
        let decode = fns.iter().find(|f| f.name == "decode").unwrap();
        assert_eq!(decode.assoc.as_deref(), Some("Policy"));
        assert!(decode.body.is_none(), "bodyless trait method");
        let name = fns.iter().find(|f| f.name == "name").unwrap();
        assert!(name.body.is_some(), "default trait body found");
        let free = fns.iter().find(|f| f.name == "free_fn").unwrap();
        assert!(free.is_pub && !free.has_self);
        assert_eq!(free.params, vec!["items", "n"]);
    }

    #[test]
    fn test_spans_cover_cfg_test_mod() {
        let code = strip(SRC).code;
        let spans = test_spans(&code);
        assert!(!spans.is_empty());
        let pos = code.find("test_only_helper").unwrap();
        assert!(in_spans(&spans, pos));
        let pos2 = code.find("free_fn").unwrap();
        assert!(!in_spans(&spans, pos2));
    }

    #[test]
    fn comma_splitting_tracks_nesting() {
        let parts = split_top_commas("a: HashMap<u64, Vec<f32>>, b: (u32, u32), c: usize");
        assert_eq!(parts.len(), 3);
        assert!(parts[0].starts_with("a:"));
        assert!(parts[1].starts_with("b:"));
    }
}
