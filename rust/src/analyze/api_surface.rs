//! API-surface conformance: extract `pub fn`/`pub struct`/`pub enum`/
//! `pub trait` declarations into a deterministic JSON document, diff it
//! against the checked-in `analyze/api_surface.json` (CI fails on
//! uncommitted drift), and arity-check inter-module call sites of
//! unambiguous public functions — the mechanized version of the manual
//! cross-check PRs 2–6 did by hand.

use super::items::{find_word, line_of, match_delim, split_top_commas};
use super::{Config, FileCtx, Finding};
use crate::jsonutil::Json;
use std::collections::BTreeMap;

pub const SCHEMA: &str = "kascade-api-surface-v1";

/// `coordinator/blocks.rs` -> `coordinator::blocks`; `sparse/mod.rs`
/// -> `sparse`.
fn module_path(rel: &str) -> String {
    let p = rel.trim_end_matches(".rs");
    let p = p.strip_suffix("/mod").unwrap_or(p);
    p.replace('/', "::")
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct PubFn {
    pub name: String,
    pub assoc: Option<String>,
    pub arity: usize,
    pub has_self: bool,
}

/// Names of `pub <kw>` items (kw = struct/enum/trait) outside tests.
fn pub_items(f: &FileCtx, kw: &str) -> Vec<String> {
    let b = f.code.as_bytes();
    let mut out = Vec::new();
    let mut at = 0usize;
    while let Some(pos) = find_word(&f.code, kw, at) {
        at = pos + kw.len();
        if f.is_test_pos(pos) || !f.code[..pos].ends_with("pub ") {
            continue;
        }
        let mut j = pos + kw.len();
        while j < b.len() && (b[j] == b' ' || b[j] == b'\n') {
            j += 1;
        }
        let start = j;
        while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
            j += 1;
        }
        if j > start {
            out.push(f.code[start..j].to_string());
        }
    }
    out.sort();
    out.dedup();
    out
}

fn pub_fns(f: &FileCtx) -> Vec<PubFn> {
    let mut out: Vec<PubFn> = f
        .fns
        .iter()
        .filter(|fun| fun.is_pub && !f.is_test_pos(fun.pos))
        .map(|fun| PubFn {
            name: fun.name.clone(),
            assoc: fun.assoc.clone(),
            arity: fun.params.len(),
            has_self: fun.has_self,
        })
        .collect();
    out.sort();
    out.dedup();
    out
}

/// Build the surface document for every scanned file.
pub fn extract(files: &[FileCtx]) -> Json {
    let mut modules = BTreeMap::new();
    for f in files {
        let structs = pub_items(f, "struct");
        let enums = pub_items(f, "enum");
        let traits = pub_items(f, "trait");
        let fns = pub_fns(f);
        if structs.is_empty() && enums.is_empty() && traits.is_empty() && fns.is_empty() {
            continue;
        }
        let fn_json = fns
            .iter()
            .map(|pf| {
                Json::obj(vec![
                    ("arity", Json::num(pf.arity as f64)),
                    (
                        "assoc",
                        match &pf.assoc {
                            Some(a) => Json::str(a.as_str()),
                            None => Json::Null,
                        },
                    ),
                    ("has_self", Json::Bool(pf.has_self)),
                    ("name", Json::str(pf.name.as_str())),
                ])
            })
            .collect::<Vec<_>>();
        let strs = |v: &[String]| Json::arr(v.iter().map(|s| Json::str(s.as_str())));
        modules.insert(
            module_path(&f.rel),
            Json::obj(vec![
                ("enums", strs(&enums)),
                ("fns", Json::arr(fn_json)),
                ("structs", strs(&structs)),
                ("traits", strs(&traits)),
            ]),
        );
    }
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("modules", Json::Obj(modules)),
    ])
}

/// Names whose call sites are never arity-checked: std-prelude
/// collisions and trait methods implemented many times over — a
/// token-level scanner cannot resolve the receiver's type, so only
/// unambiguous repo-unique names are checked.
const SKIP_NAMES: [&str; 32] = [
    "new", "default", "len", "get", "push", "pop", "insert", "remove", "clear", "iter", "next",
    "clone", "from", "into", "drop", "send", "recv", "write", "read", "take", "name", "reset",
    "parse", "sample", "step", "run", "min", "max", "extend", "path", "join", "bind",
];

/// Arity-check call sites of unambiguous pub fns across every file.
/// "Unambiguous" counts EVERY definition, private ones included — a
/// private `fn preempt(victim, batch)` next to a pub
/// `Sequence::preempt(backend)` makes the name unresolvable for a
/// token-level scanner.
fn call_sites(files: &[FileCtx], fns: &[(String, PubFn)]) -> Vec<Finding> {
    // name -> signature, keeping only names where all definitions
    // (pub, private, trait) agree
    let mut sigs: BTreeMap<String, Option<PubFn>> = BTreeMap::new();
    let mut all_defs = Vec::new();
    for f in files {
        for fun in &f.fns {
            if f.is_test_pos(fun.pos) {
                continue;
            }
            all_defs.push(PubFn {
                name: fun.name.clone(),
                assoc: None,
                arity: fun.params.len(),
                has_self: fun.has_self,
            });
        }
    }
    for pf in &all_defs {
        sigs.entry(pf.name.clone())
            .and_modify(|cur| {
                let same = cur
                    .as_ref()
                    .map(|c| c.arity == pf.arity && c.has_self == pf.has_self)
                    .unwrap_or(false);
                if !same {
                    *cur = None;
                }
            })
            .or_insert_with(|| Some(pf.clone()));
    }
    let pub_names: Vec<&str> = fns.iter().map(|(_, pf)| pf.name.as_str()).collect();
    let checkable: Vec<&PubFn> = sigs
        .values()
        .flatten()
        .filter(|pf| {
            pf.name.len() >= 4
                && !SKIP_NAMES.contains(&pf.name.as_str())
                && pub_names.contains(&pf.name.as_str())
        })
        .collect();

    let mut out = Vec::new();
    for f in files {
        for pf in &checkable {
            let mut at = 0usize;
            while let Some(pos) = find_word(&f.code, &pf.name, at) {
                at = pos + pf.name.len();
                if f.is_test_pos(pos) {
                    continue;
                }
                let before = f.code[..pos].trim_end();
                let last_word_is = |w: &str| {
                    before.ends_with(w)
                        && !before[..before.len() - w.len()]
                            .ends_with(|c: char| c == '_' || c.is_ascii_alphanumeric())
                };
                if last_word_is("fn") || last_word_is("use") {
                    continue;
                }
                let rest = &f.code[pos + pf.name.len()..];
                if !rest.starts_with('(') {
                    continue;
                }
                let open = pos + pf.name.len();
                let Some(close) = match_delim(&f.code, open) else { continue };
                let args_text = &f.code[open + 1..close];
                if has_top_level_pipe(args_text) {
                    continue; // closure arguments defeat comma counting
                }
                let got = split_top_commas(args_text).len();
                let is_method = before.ends_with('.');
                let ok = if is_method {
                    pf.has_self && got == pf.arity
                } else if pf.has_self {
                    // UFCS / `Type::method(&x, ..)` or a same-name local
                    got == pf.arity || got == pf.arity + 1
                } else {
                    got == pf.arity
                };
                if !ok {
                    out.push(Finding {
                        rule: "api-surface",
                        file: f.rel.clone(),
                        line: line_of(&f.code, pos),
                        msg: format!(
                            "call to `{}` passes {got} arg(s) but the API surface \
                             declares arity {} (has_self: {})",
                            pf.name, pf.arity, pf.has_self
                        ),
                    });
                }
            }
        }
    }
    out
}

fn has_top_level_pipe(text: &str) -> bool {
    let mut depth = 0i32;
    for ch in text.chars() {
        match ch {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            '|' if depth == 0 => return true,
            _ => {}
        }
    }
    false
}

/// Full api-surface pass: call-site conformance plus drift against the
/// committed surface file (or regeneration with `write_api`).
pub fn check(files: &[FileCtx], config: &Config, write_api: bool) -> std::io::Result<Vec<Finding>> {
    let all_fns: Vec<(String, PubFn)> = files
        .iter()
        .flat_map(|f| pub_fns(f).into_iter().map(move |pf| (f.rel.clone(), pf)))
        .collect();
    let mut out = call_sites(files, &all_fns);

    let Some(path) = &config.api_surface_path else {
        return Ok(out);
    };
    let fresh = extract(files);
    if write_api {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, fresh.to_string() + "\n")?;
        return Ok(out);
    }
    let shown = path.display().to_string();
    let committed = match Json::from_file(path) {
        Ok(j) => j,
        Err(e) => {
            out.push(Finding {
                rule: "api-surface",
                file: shown,
                line: 0,
                msg: format!("cannot load committed surface ({e:#}) — run with --write-api"),
            });
            return Ok(out);
        }
    };
    if committed != fresh {
        let empty = BTreeMap::new();
        let cm = committed.get("modules").and_then(|m| m.as_obj()).unwrap_or(&empty);
        let fm = fresh.get("modules").and_then(|m| m.as_obj()).unwrap_or(&empty);
        let mut drifted: Vec<&String> = Vec::new();
        for k in cm.keys().chain(fm.keys()) {
            if cm.get(k) != fm.get(k) && !drifted.contains(&k) {
                drifted.push(k);
            }
        }
        out.push(Finding {
            rule: "api-surface",
            file: shown,
            line: 0,
            msg: format!(
                "committed API surface is stale (drift in: {}) — regenerate with \
                 `cargo run --bin kascade_analyze -- --write-api` and commit",
                drifted
                    .iter()
                    .map(|s| s.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(rel: &str, src: &str) -> FileCtx {
        FileCtx::parse(rel.into(), src)
    }

    #[test]
    fn extracts_modules_types_and_fns() {
        let f = ctx(
            "coordinator/blocks.rs",
            "pub struct BlockManager;\npub enum Kind { A }\n\
             impl BlockManager {\n    pub fn extend(&mut self, seq: u64, n: usize) -> bool { true }\n}\n\
             pub fn free_fn(a: usize) {}\n",
        );
        let j = extract(&[f]);
        assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some(SCHEMA));
        let m = j.path("modules.coordinator::blocks").unwrap();
        assert_eq!(m.get("structs").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(m.get("enums").unwrap().as_arr().unwrap().len(), 1);
        let fns = m.get("fns").unwrap().as_arr().unwrap();
        assert_eq!(fns.len(), 2);
        let ext = fns.iter().find(|x| x.get("name").unwrap().as_str() == Some("extend")).unwrap();
        assert_eq!(ext.get("arity").unwrap().as_usize(), Some(2));
        assert_eq!(ext.get("assoc").unwrap().as_str(), Some("BlockManager"));
    }

    #[test]
    fn call_site_arity_mismatch_is_flagged() {
        let lib = ctx("widgets.rs", "pub fn widgetize(a: usize, b: usize) -> usize { a + b }\n");
        let good = ctx("ok.rs", "fn f() { let x = widgetize(1, 2); }\n");
        let bad = ctx("bad.rs", "fn g() { let x = widgetize(1, 2, 3); }\n");
        let files = vec![lib, good, bad];
        let fns: Vec<(String, PubFn)> = files
            .iter()
            .flat_map(|f| pub_fns(f).into_iter().map(move |pf| (f.rel.clone(), pf)))
            .collect();
        let out = call_sites(&files, &fns);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].file, "bad.rs");
        assert!(out[0].msg.contains("arity 2"));
    }

    #[test]
    fn ambiguous_and_stoplisted_names_are_skipped() {
        let a = ctx("a.rs", "pub fn overloadish(a: usize) {}\n");
        let b = ctx("b.rs", "pub fn overloadish(a: usize, b: usize) {}\n");
        let call = ctx("c.rs", "fn f() { overloadish(1, 2, 3); }\n");
        let files = vec![a, b, call];
        let fns: Vec<(String, PubFn)> = files
            .iter()
            .flat_map(|f| pub_fns(f).into_iter().map(move |pf| (f.rel.clone(), pf)))
            .collect();
        assert!(call_sites(&files, &fns).is_empty(), "conflicting sigs are not checkable");
    }
}
