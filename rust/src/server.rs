//! Serving front-end: a synchronous [`Engine`] (scheduler + sequences +
//! metrics, fully testable single-threaded) and a thread-based [`Server`]
//! that runs one engine per worker with a session-affinity router in
//! front.  (tokio is unavailable in this offline environment; the event
//! loop is std::thread + mpsc, which on a 1-core host is the same thing.)

use crate::config::ServeConfig;
use crate::coordinator::{Request, Router, Scheduler, SeqBackend, SeqPhase, Sequence, ServeMetrics, WorkItem};
use crate::model::{DecodeReq, Model};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Bound on retained prefix-cache snapshots: each is a full backend
/// state clone at a chunk boundary, so an uncapped engine would hold
/// O(prompt_len / prefill_chunk) cumulative clones per distinct prefix.
/// Oldest boundaries are dropped first and un-flagged in the index, so
/// the scheduler simply stops matching at them.
const MAX_SNAPSHOTS: usize = 256;

/// Factory creating a fresh backend for a request (also used on
/// preemption-recompute).  The `Send` variant crosses into worker threads
/// ([`Server`]); the local variant serves the single-threaded [`Engine`]
/// (e.g. the Rc-based PJRT backend).
pub type BackendFactory = Box<dyn Fn(&Request) -> Box<dyn SeqBackend> + Send>;
pub type LocalBackendFactory = Box<dyn Fn(&Request) -> Box<dyn SeqBackend>>;

/// Finished-request report.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub preemptions: usize,
    /// prompt tokens whose prefill was skipped via the prefix cache
    pub cached_prefix_tokens: usize,
}

/// Single-threaded serving engine: owns the scheduler and live sequences.
pub struct Engine {
    pub sched: Scheduler,
    pub seqs: HashMap<u64, Sequence>,
    pub metrics: ServeMetrics,
    factory: LocalBackendFactory,
    finished: Vec<Completion>,
    /// prefix-cache state snapshots, keyed by the chain hash of the
    /// block-aligned prompt boundary they hold (see `coordinator::prefix_cache`)
    snapshots: HashMap<u64, Box<dyn SeqBackend>>,
    /// snapshot insertion order, for [`MAX_SNAPSHOTS`] eviction.  May
    /// transiently contain hashes already pruned by index invalidation;
    /// the tick loop compacts those away once they outnumber live
    /// entries, keeping the queue O(live snapshots)
    snapshot_order: VecDeque<u64>,
}

impl Engine {
    pub fn new(cfg: ServeConfig, factory: LocalBackendFactory) -> Self {
        Self {
            sched: Scheduler::new(cfg),
            seqs: HashMap::new(),
            metrics: ServeMetrics::new(),
            factory,
            finished: Vec::new(),
            snapshots: HashMap::new(),
            snapshot_order: VecDeque::new(),
        }
    }

    /// Returns false if admission control rejected the request.
    pub fn submit(&mut self, req: Request) -> bool {
        let id = req.id;
        if !self.sched.submit_with_prompt(id, &req.prompt) {
            return false;
        }
        let backend = (self.factory)(&req);
        self.metrics.prompts_in += 1;
        self.seqs.insert(id, Sequence::new(req, backend));
        true
    }

    pub fn idle(&self) -> bool {
        self.sched.running.is_empty() && self.sched.waiting.is_empty()
    }

    /// One scheduler tick: form a batch, execute it, retire finished.
    /// Returns the number of work items executed.
    pub fn tick(&mut self) -> usize {
        let batch = {
            let seqs = &self.seqs;
            self.sched.tick(|id| {
                seqs.get(&id)
                    .map(|s| (s.phase, s.req.prompt.len(), s.req.prompt.len() + s.emitted.len()))
            })
        };
        // drop snapshots whose index entries died with blocks evicted
        // during batch formation — BEFORE this tick registers anything,
        // so a recycled block can never leave a stale entry behind
        for h in self.sched.take_invalidated() {
            self.snapshots.remove(&h);
        }
        // compact stale order entries (hashes the invalidation path
        // pruned from the map): without this the queue grows without
        // bound under index churn, one dead hash per evicted boundary.
        // Amortized O(1): compaction restores order.len() == map len.
        if self.snapshot_order.len() > 2 * self.snapshots.len().max(32) {
            let live = &self.snapshots;
            self.snapshot_order.retain(|h| live.contains_key(h));
        }
        for &victim in &batch.preempted {
            if let Some(s) = self.seqs.get_mut(&victim) {
                // the discarded backend's dequant accounting would vanish
                // with it (the fresh one restarts at 0) — fold it now;
                // retire() later adds only the post-restart count
                if let Some(ks) = s.backend.kv_stats() {
                    self.metrics.dequant_rows += ks.dequant_rows;
                }
                let fresh = (self.factory)(&s.req);
                s.preempt(fresh);
                // emitted tokens folded into the prompt: re-hash so the
                // re-admission can match its own cached prefix blocks
                self.sched.set_prompt(victim, &s.req.prompt);
                self.metrics.preemptions += 1;
            }
        }
        // prefix-cache resumes: install snapshot state and fast-forward
        // past the adopted blocks before any work executes
        for &(seq, tokens, hash) in &batch.cache_hits {
            let snap = self.snapshots.get(&hash).and_then(|b| b.fork_prefix(tokens));
            debug_assert!(snap.is_some(), "resumable boundary without a snapshot");
            if let Some(b) = snap {
                if let Some(s) = self.seqs.get_mut(&seq) {
                    s.fast_forward(tokens, b);
                    self.metrics.prefix_hits += 1;
                    self.metrics.saved_prefill_tokens += tokens as u64;
                }
            }
            // on a vanished snapshot the sequence stays Waiting-shaped
            // (done = 0) and simply prefills from scratch — the adopted
            // blocks only over-reserve, they never corrupt outputs
        }
        self.metrics.prefix_misses += batch.cache_misses;
        let n = batch.items.len();
        self.metrics.batch_size.add(n as f64);
        // split the tick: decodes execute first (scheduler order) as one
        // step-batched forward per shared model, then prefill chunks
        let mut decode_ids: Vec<u64> = Vec::new();
        let mut prefills: Vec<(u64, usize)> = Vec::new();
        for item in batch.items {
            match item {
                WorkItem::Decode { seq } => decode_ids.push(seq),
                WorkItem::Prefill { seq, tokens } => prefills.push((seq, tokens)),
            }
        }
        self.run_decodes(&decode_ids);
        for (seq, tokens) in prefills {
            if let Some(s) = self.seqs.get_mut(&seq) {
                s.step_prefill(tokens);
            }
            self.register_prefix(seq);
        }
        self.metrics.kv_util.add(self.sched.blocks.utilization());
        self.metrics.kv_cached.add(self.sched.blocks.cached() as f64);
        let kv_bytes: usize = self
            .seqs
            .values()
            .filter_map(|s| s.backend.kv_stats().map(|k| k.bytes))
            .sum();
        self.metrics.sample_kv_bytes(kv_bytes);
        self.retire();
        n
    }

    /// Execute one tick's decode work items.  With
    /// [`ServeConfig::batched_decode`], every batch-capable sequence
    /// sharing a model runs through ONE layer-major
    /// [`Model::decode_batch`] pass — logits bitwise-identical to the
    /// sequential path, weight reads amortized across the batch.
    /// Sequences with buffered prefill logits (no forward needed),
    /// non-batchable backends (PJRT, test doubles), and — on mixed
    /// ticks — sequences of a different model fall back sequentially.
    fn run_decodes(&mut self, ids: &[u64]) {
        if ids.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let use_batch = self.sched.cfg.batched_decode;
        let metrics = &mut self.metrics;
        let idset: HashSet<u64> = ids.iter().copied().collect();
        let mut by_id: HashMap<u64, &mut Sequence> = self
            .seqs
            .iter_mut()
            .filter(|(id, _)| idset.contains(id))
            .map(|(&id, s)| (id, s))
            .collect();
        let mut tokens_done = 0u64;
        let mut rest: Vec<&mut Sequence> = Vec::new();
        for id in ids {
            let s = match by_id.remove(id) {
                Some(s) => s,
                None => continue,
            };
            if use_batch && s.decode_input().is_some() && s.backend.batch_parts().is_some() {
                rest.push(s);
            } else {
                s.step_decode();
                tokens_done += 1;
            }
        }
        // group by shared model (Arc identity), one batched pass per group
        while !rest.is_empty() {
            let mut group: Vec<&mut Sequence> = Vec::new();
            let mut next: Vec<&mut Sequence> = Vec::new();
            let mut key: Option<*const Model> = None;
            for s in rest {
                let ptr = s.backend.batch_parts().map(|p| Arc::as_ptr(p.model));
                match (key, ptr) {
                    (None, Some(p)) => {
                        key = Some(p);
                        group.push(s);
                    }
                    (Some(kp), Some(p)) if p == kp => group.push(s),
                    (_, Some(_)) => next.push(s),
                    // backend stopped being batchable since the probe:
                    // decode it sequentially rather than panic/livelock
                    (_, None) => {
                        s.step_decode();
                        tokens_done += 1;
                    }
                }
            }
            rest = next;
            if group.is_empty() {
                continue;
            }
            let model: Arc<Model> = {
                let parts = group[0].backend.batch_parts().expect("probed batchable");
                parts.model.clone()
            };
            let mut reqs: Vec<DecodeReq> = Vec::with_capacity(group.len());
            for s in group.iter_mut() {
                let token = s.decode_input().expect("probed: logits not buffered");
                let parts = s.backend.batch_parts().expect("probed batchable");
                reqs.push(DecodeReq { token, st: parts.st, policy: parts.policy });
            }
            let logits = model.decode_batch(&mut reqs);
            drop(reqs);
            metrics.decode_batch.add_us(group.len() as f64);
            for (s, l) in group.iter_mut().zip(logits.iter()) {
                s.apply_decoded_logits(l);
                tokens_done += 1;
            }
        }
        let dt_us = t0.elapsed().as_secs_f64() * 1e6;
        metrics.tokens_out += tokens_done;
        metrics.decode_tokens += tokens_done;
        metrics.decode_time_us += dt_us;
        if tokens_done > 0 {
            let per_tok = dt_us / tokens_done as f64;
            for _ in 0..tokens_done {
                metrics.tpot_us.add(per_tok);
            }
        }
    }

    /// After prefill work lands for `seq`, publish its newly completed
    /// full prompt blocks in the prefix index and store a backend state
    /// snapshot at the block-aligned boundary so later sequences with
    /// the same prefix can resume there.
    fn register_prefix(&mut self, seq: u64) {
        if !self.sched.cfg.enable_prefix_cache {
            return;
        }
        let s = match self.seqs.get(&seq) {
            Some(s) => s,
            None => return,
        };
        let done = match s.phase {
            SeqPhase::Prefilling { done } => done,
            SeqPhase::Decoding | SeqPhase::Finished => s.req.prompt.len(),
            SeqPhase::Waiting => return,
        };
        let bs = self.sched.cfg.block_size;
        let plen = s.req.prompt.len();
        // cap below the prompt end: the final token is always computed
        // fresh so the resumed sequence produces first-token logits
        let boundary = done.min(plen.saturating_sub(1)) / bs * bs;
        if boundary == 0 {
            return;
        }
        if let Some(hash) = self.sched.snapshot_wanted(seq, boundary) {
            if let Some(snap) = s.backend.fork_prefix(boundary) {
                self.sched.register_prefix(seq, boundary, true);
                if self.snapshots.insert(hash, snap).is_none() {
                    self.snapshot_order.push_back(hash);
                }
                while self.snapshots.len() > MAX_SNAPSHOTS {
                    let old = match self.snapshot_order.pop_front() {
                        Some(h) => h,
                        None => break,
                    };
                    if self.snapshots.remove(&old).is_some() {
                        self.sched.prefix.unmark_resumable(old);
                    }
                }
            }
        }
    }

    fn retire(&mut self) {
        let done_ids: Vec<u64> = self
            .seqs
            .iter()
            .filter(|(_, s)| s.is_finished())
            .map(|(&id, _)| id)
            .collect();
        for id in done_ids {
            self.sched.on_finished(id);
            let s = self.seqs.remove(&id).unwrap();
            if let Some(ks) = s.backend.kv_stats() {
                self.metrics.dequant_rows += ks.dequant_rows;
            }
            if let Some(t) = s.first_token_at {
                self.metrics
                    .ttft_us
                    .add_us(t.duration_since(s.arrived).as_secs_f64() * 1e6);
            }
            self.metrics.requests_done += 1;
            self.finished.push(Completion {
                id,
                // includes tokens folded into the prompt by preemption —
                // a preempted request completes with identical output
                tokens: s.response_tokens(),
                ttft_ms: s
                    .first_token_at
                    .map(|t| t.duration_since(s.arrived).as_secs_f64() * 1e3)
                    .unwrap_or(0.0),
                total_ms: s
                    .finished_at
                    .map(|t| t.duration_since(s.arrived).as_secs_f64() * 1e3)
                    .unwrap_or(0.0),
                preemptions: s.preemptions,
                cached_prefix_tokens: s.cached_prefix,
            });
        }
    }

    pub fn drain_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// Run until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Vec<Completion> {
        let mut guard = 0usize;
        while !self.idle() {
            let did = self.tick();
            guard = if did == 0 { guard + 1 } else { 0 };
            assert!(guard < 1000, "scheduler livelock: no work for 1000 ticks");
        }
        self.drain_finished()
    }
}

enum Msg {
    Submit(Request, Sender<Completion>),
    Shutdown,
}

/// Multi-worker server: router + one engine thread per worker.
pub struct Server {
    router: Router,
    txs: Vec<Sender<Msg>>,
    handles: Vec<std::thread::JoinHandle<ServeMetrics>>,
}

impl Server {
    /// `factories` — one backend factory per worker.
    pub fn start(cfg: ServeConfig, factories: Vec<BackendFactory>) -> Self {
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for factory in factories {
            let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let mut engine = Engine::new(cfg, factory);
                let mut replies: HashMap<u64, Sender<Completion>> = HashMap::new();
                let mut open = true;
                loop {
                    // drain incoming without blocking while work remains
                    loop {
                        let msg = if engine.idle() && open {
                            rx.recv().ok()
                        } else {
                            match rx.try_recv() {
                                Ok(m) => Some(m),
                                Err(_) => None,
                            }
                        };
                        match msg {
                            Some(Msg::Submit(req, reply)) => {
                                replies.insert(req.id, reply);
                                engine.submit(req);
                            }
                            Some(Msg::Shutdown) => open = false,
                            None => break,
                        }
                    }
                    if engine.idle() {
                        if !open {
                            break;
                        }
                        continue;
                    }
                    engine.tick();
                    for c in engine.drain_finished() {
                        if let Some(reply) = replies.remove(&c.id) {
                            let _ = reply.send(c);
                        }
                    }
                }
                engine.metrics
            }));
            txs.push(tx);
        }
        Self { router: Router::new(txs.len()), txs, handles }
    }

    /// Submit a request; the completion arrives on the returned receiver.
    pub fn submit(&mut self, req: Request, session: Option<u64>) -> Receiver<Completion> {
        let (tx, rx) = channel();
        let w = self.router.route(session);
        self.txs[w].send(Msg::Submit(req, tx)).expect("worker alive");
        rx
    }

    /// Shut down and collect per-worker metrics.
    pub fn shutdown(self) -> Vec<ServeMetrics> {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        self.handles.into_iter().map(|h| h.join().unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::test_backend::ToyBackend;

    fn cfg() -> ServeConfig {
        ServeConfig {
            block_size: 16,
            num_blocks: 128,
            max_running: 4,
            token_budget: 128,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            ..ServeConfig::default()
        }
    }

    fn toy_factory() -> BackendFactory {
        Box::new(|_req| Box::new(ToyBackend::new(64)))
    }

    #[test]
    fn engine_completes_all_requests() {
        let mut e = Engine::new(cfg(), toy_factory());
        for id in 0..10 {
            assert!(e.submit(Request {
                id,
                prompt: vec![0; 100 + 13 * id as usize],
                max_new: 5,
                stop_token: None,
            }));
        }
        let done = e.run_to_completion();
        assert_eq!(done.len(), 10);
        for c in &done {
            assert_eq!(c.tokens.len(), 5);
        }
        assert_eq!(e.metrics.requests_done, 10);
        assert_eq!(e.metrics.tokens_out, 50);
        e.sched.blocks.check_invariants().unwrap();
        assert_eq!(e.sched.blocks.used(), 0, "all blocks released");
    }

    #[test]
    fn engine_survives_memory_pressure_with_preemption() {
        let tight = ServeConfig { num_blocks: 12, max_running: 8, ..cfg() }; // 192 tokens
        let mut e = Engine::new(tight, toy_factory());
        for id in 0..6 {
            e.submit(Request { id, prompt: vec![0; 40], max_new: 30, stop_token: None });
        }
        let done = e.run_to_completion();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.tokens.len(), 30, "req {} emitted {}", c.id, c.tokens.len());
        }
        e.sched.blocks.check_invariants().unwrap();
    }

    /// Null-compute backend whose state is just a token count, with
    /// prefix-snapshot support — lets tests drive the snapshot/index
    /// machinery without a model.
    struct ForkableToy {
        tokens: usize,
    }

    impl SeqBackend for ForkableToy {
        fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
            self.tokens += tokens.len();
            Some(vec![0.0, 1.0])
        }

        fn decode(&mut self, _token: u32) -> Vec<f32> {
            self.tokens += 1;
            vec![0.0, 1.0]
        }

        fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
            if tokens > self.tokens {
                return None;
            }
            Some(Box::new(ForkableToy { tokens }))
        }
    }

    /// `snapshot_order` used to accumulate one dead hash per boundary
    /// whose snapshot was pruned by index invalidation (block eviction
    /// under pressure) — unbounded growth under churn.  The tick loop
    /// now compacts stale entries; this churns hundreds of distinct
    /// prompts through a tiny pool and asserts the queue stays
    /// proportional to the live snapshot count.
    #[test]
    fn snapshot_order_stays_bounded_under_invalidation_churn() {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 16, // 256 tokens: constant eviction pressure
            max_running: 2,
            token_budget: 256,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 16,
            ..ServeConfig::default()
        };
        let mut e = Engine::new(cfg, Box::new(|_req| Box::new(ForkableToy { tokens: 0 })));
        for id in 0..600u64 {
            // distinct prompts: every admission registers fresh boundaries
            // and evicts someone else's blocks (invalidating their hashes)
            let prompt: Vec<u32> = (0..64).map(|j| (id * 64 + j) as u32).collect();
            assert!(e.submit(Request { id, prompt, max_new: 2, stop_token: None }));
            e.run_to_completion();
        }
        assert!(
            // threshold + a tick's worth of registrations (compaction
            // runs at the START of the next tick)
            e.snapshot_order.len() <= 2 * e.snapshots.len().max(32) + 8,
            "snapshot_order grew to {} with only {} live snapshots",
            e.snapshot_order.len(),
            e.snapshots.len()
        );
        e.sched.blocks.check_invariants().unwrap();
    }

    #[test]
    fn server_round_trips_across_workers() {
        let mut srv = Server::start(cfg(), vec![toy_factory(), toy_factory()]);
        let mut rxs = Vec::new();
        for id in 0..8 {
            rxs.push(srv.submit(
                Request { id, prompt: vec![0; 64], max_new: 3, stop_token: None },
                Some(id % 3),
            ));
        }
        for rx in rxs {
            let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(c.tokens.len(), 3);
        }
        let metrics = srv.shutdown();
        let total: u64 = metrics.iter().map(|m| m.requests_done).sum();
        assert_eq!(total, 8);
    }
}
