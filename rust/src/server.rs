//! Serving front-end: a synchronous [`Engine`] (scheduler + sequences +
//! metrics, fully testable single-threaded) and a thread-based [`Server`]
//! that runs one engine per worker with a session-affinity router in
//! front.  (tokio is unavailable in this offline environment; the event
//! loop is std::thread + mpsc, which on a 1-core host is the same thing.)
//!
//! Both expose the streaming session API ([`crate::coordinator::api`]):
//! `submit` returns a typed `Result<RequestHandle, SubmitError>`, the
//! handle streams `Started` / `Token` / `Done` / `Failed` events per
//! tick, and `cancel()` (or deadline expiry) tears the request down
//! inside the engine within one tick — every KV block released, indexed
//! blocks parked in the prefix-cache pool with their snapshots intact.

use crate::config::ServeConfig;
use crate::coordinator::{
    handle_pair, Router, Scheduler, SeqBackend, SeqPhase, Sequence, ServeMetrics, Session,
    WorkItem,
};
use crate::model::{BatchScratch, DecodeReq, Model};
use crate::pool::WorkerPool;
use crate::stats::{LatencyHist, Timer};

/// The session API, re-exported so front-end callers can pull everything
/// from one module.
pub use crate::coordinator::api::{
    Completion, Event, FailReason, Request, RequestHandle, SubmitError,
};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Bound on retained prefix-cache snapshots: each is a full backend
/// state clone at a chunk boundary, so an uncapped engine would hold
/// O(prompt_len / prefill_chunk) cumulative clones per distinct prefix.
/// Oldest boundaries are dropped first and un-flagged in the index, so
/// the scheduler simply stops matching at them.
const MAX_SNAPSHOTS: usize = 256;

/// Factory creating a fresh backend for a request (also used on
/// preemption-recompute).  The `Send` variant crosses into worker threads
/// ([`Server`]); the local variant serves the single-threaded [`Engine`]
/// (e.g. the Rc-based PJRT backend).
pub type BackendFactory = Box<dyn Fn(&Request) -> Box<dyn SeqBackend> + Send>;
pub type LocalBackendFactory = Box<dyn Fn(&Request) -> Box<dyn SeqBackend>>;

/// Single-threaded serving engine: owns the scheduler and live sequences.
pub struct Engine {
    pub sched: Scheduler,
    pub seqs: HashMap<u64, Sequence>,
    pub metrics: ServeMetrics,
    factory: LocalBackendFactory,
    /// next auto-assigned request id (see [`Engine::submit`])
    next_id: u64,
    /// prefix-cache state snapshots, keyed by the chain hash of the
    /// block-aligned prompt boundary they hold (see `coordinator::prefix_cache`)
    snapshots: HashMap<u64, Box<dyn SeqBackend>>,
    /// snapshot insertion order, for [`MAX_SNAPSHOTS`] eviction.  May
    /// transiently contain hashes already pruned by index invalidation;
    /// the tick loop compacts those away once they outnumber live
    /// entries, keeping the queue O(live snapshots)
    snapshot_order: VecDeque<u64>,
    /// persistent staging for the step-batched decode pass — reused every
    /// tick so the steady-state decode loop allocates nothing
    batch_scratch: BatchScratch,
    /// persistent workers for the parallel tick
    /// ([`ServeConfig::num_threads`] > 1); `None` = serial
    pool: Option<WorkerPool>,
}

impl Engine {
    pub fn new(cfg: ServeConfig, factory: LocalBackendFactory) -> Self {
        let threads = cfg.num_threads.max(1);
        let pool = (threads > 1).then(|| WorkerPool::new(threads));
        let mut metrics = ServeMetrics::new();
        metrics.threads = threads;
        Self {
            sched: Scheduler::new(cfg),
            seqs: HashMap::new(),
            metrics,
            factory,
            next_id: 0,
            snapshots: HashMap::new(),
            snapshot_order: VecDeque::new(),
            batch_scratch: BatchScratch::new(),
            pool,
        }
    }

    /// Submit a request: typed admission, streaming handle back.  The
    /// engine assigns the request id (monotonic per engine), readable
    /// via [`RequestHandle::id`] and on the final [`Completion`].
    pub fn submit(&mut self, req: Request) -> Result<RequestHandle, SubmitError> {
        let id = self.next_id;
        let (handle, session) = handle_pair(id, self.metrics.streamed_ttft_us.clone());
        self.submit_session(id, req, session)?;
        Ok(handle)
    }

    /// Submit with an externally created session under an explicit id —
    /// the [`Server`]'s workers route pre-built handles here.  On
    /// rejection the session receives the terminal `Failed(Rejected)`
    /// event *and* the error is returned.
    pub fn submit_session(
        &mut self,
        id: u64,
        req: Request,
        session: Session,
    ) -> Result<(), SubmitError> {
        assert!(!self.seqs.contains_key(&id), "duplicate request id {id}");
        self.next_id = self.next_id.max(id + 1);
        // a prompt the pool cannot hold alongside one decode token would
        // stall admission forever — reject it up front, typed
        let pool = self.sched.cfg.num_blocks * self.sched.cfg.block_size;
        let limit = self
            .sched
            .cfg
            .max_prompt_tokens
            .unwrap_or(usize::MAX)
            .min(pool.saturating_sub(1));
        if req.prompt.len() > limit {
            let e = SubmitError::PromptTooLong { prompt: req.prompt.len(), limit };
            session.send(Event::Failed(FailReason::Rejected(e)));
            return Err(e);
        }
        if !self.sched.submit_request(id, &req.prompt, req.priority) {
            let e = SubmitError::QueueFull;
            session.send(Event::Failed(FailReason::Rejected(e)));
            return Err(e);
        }
        self.sched.set_tenant(id, req.tenant);
        let backend = (self.factory)(&req);
        self.metrics.prompts_in += 1;
        self.seqs.insert(id, Sequence::new(req, session, backend));
        Ok(())
    }

    pub fn idle(&self) -> bool {
        self.sched.running.is_empty() && self.sched.waiting.is_empty()
    }

    /// One scheduler tick: apply cancellations/deadlines, form a batch,
    /// execute it, retire finished.  Returns the number of work items
    /// executed.
    pub fn tick(&mut self) -> usize {
        let tick_timer = Timer::start();
        self.sweep_sessions();
        let batch = {
            let seqs = &self.seqs;
            self.sched.tick(|id| {
                seqs.get(&id)
                    .map(|s| (s.phase, s.req.prompt.len(), s.req.prompt.len() + s.emitted.len()))
            })
        };
        // drop snapshots whose index entries died with blocks evicted
        // during batch formation — BEFORE this tick registers anything,
        // so a recycled block can never leave a stale entry behind
        for h in self.sched.take_invalidated() {
            self.snapshots.remove(&h);
        }
        // compact stale order entries (hashes the invalidation path
        // pruned from the map): without this the queue grows without
        // bound under index churn, one dead hash per evicted boundary.
        // Amortized O(1): compaction restores order.len() == map len.
        if self.snapshot_order.len() > 2 * self.snapshots.len().max(32) {
            let live = &self.snapshots;
            self.snapshot_order.retain(|h| live.contains_key(h));
        }
        for &victim in &batch.preempted {
            if let Some(s) = self.seqs.get_mut(&victim) {
                // the discarded backend's dequant accounting would vanish
                // with it (the fresh one restarts at 0) — fold it now;
                // retire() later adds only the post-restart count
                if let Some(ks) = s.backend.kv_stats() {
                    self.metrics.dequant_rows += ks.dequant_rows;
                }
                let fresh = (self.factory)(&s.req);
                s.preempt(fresh);
                // emitted tokens folded into the prompt: re-hash so the
                // re-admission can match its own cached prefix blocks
                self.sched.set_prompt(victim, &s.req.prompt);
                self.metrics.preemptions += 1;
            }
        }
        // prefix-cache resumes: install snapshot state and fast-forward
        // past the adopted blocks before any work executes
        for &(seq, tokens, hash) in &batch.cache_hits {
            let snap = self.snapshots.get(&hash).and_then(|b| b.fork_prefix(tokens));
            debug_assert!(snap.is_some(), "resumable boundary without a snapshot");
            if let Some(b) = snap {
                if let Some(s) = self.seqs.get_mut(&seq) {
                    s.fast_forward(tokens, b);
                    self.metrics.prefix_hits += 1;
                    self.metrics.saved_prefill_tokens += tokens as u64;
                }
            }
            // on a vanished snapshot the sequence stays Waiting-shaped
            // (done = 0) and simply prefills from scratch — the adopted
            // blocks only over-reserve, they never corrupt outputs
        }
        self.metrics.prefix_misses += batch.cache_misses;
        let n = batch.items.len();
        self.metrics.batch_size.add(n as f64);
        self.metrics.prefill_tokens_per_tick.add(batch.prefill_tokens() as f64);
        // split the tick: decodes execute first (scheduler order) as one
        // step-batched forward per shared model, then prefill chunks
        let mut decode_ids: Vec<u64> = Vec::new();
        let mut prefills: Vec<(u64, usize)> = Vec::new();
        for item in batch.items {
            match item {
                WorkItem::Decode { seq } => decode_ids.push(seq),
                WorkItem::Prefill { seq, tokens } => prefills.push((seq, tokens)),
            }
        }
        self.run_decodes(&decode_ids);
        for (seq, tokens) in prefills {
            if let Some(s) = self.seqs.get_mut(&seq) {
                s.step_prefill(tokens);
            }
            self.register_prefix(seq);
        }
        // tick-boundary tier maintenance (docs/kv-tiers.md): replan each
        // tiered sequence's hot set from its policy's latest Top-k hints
        // and apply promotions/demotions HERE, between ticks, so the
        // deterministic decode pass never observes a mid-step tier change
        if self.sched.cfg.kv_tiers {
            let mut ids: Vec<u64> = self.sched.running.clone();
            // plan in id order: LRU stamps and spill writes replay exactly
            ids.sort_unstable();
            for id in ids {
                if let Some(s) = self.seqs.get_mut(&id) {
                    if let Some(stats) = s.tier_maintenance(id, &mut self.sched.blocks) {
                        self.metrics.add_tier_stats(&stats);
                    }
                }
            }
        }
        self.metrics.kv_util.add(self.sched.blocks.utilization());
        self.metrics.kv_cached.add(self.sched.blocks.cached() as f64);
        let kv_bytes: usize = self
            // analyze: allow(determinism) — order-insensitive integer sum
            .seqs
            .values()
            .filter_map(|s| s.backend.kv_stats().map(|k| k.bytes))
            .sum();
        self.metrics.sample_kv_bytes(kv_bytes);
        self.retire();
        self.metrics.tick_us.add(tick_timer.us());
        n
    }

    /// Apply client cancellations and expired deadlines: the sequence
    /// leaves the scheduler (waiting or running), releases every KV
    /// block it holds (indexed blocks park in the prefix-cache pool, so
    /// engine-held snapshots stay valid), and the handle receives the
    /// terminal `Failed` event carrying the partial completion.  Runs at
    /// the top of every tick — a mid-stream `cancel()` reclaims all
    /// blocks within one tick.
    fn sweep_sessions(&mut self) {
        // analyze: allow(determinism) — deadline sweep samples the tick clock once
        let now = Instant::now();
        let mut ended: Vec<(u64, bool)> = Vec::new(); // (id, deadline?)
        // analyze: allow(determinism) — pure filter; `ended` is sorted before teardown
        for (&id, s) in &self.seqs {
            if s.cancel_requested() {
                ended.push((id, false));
            } else if s.past_deadline(now) {
                ended.push((id, true));
            }
        }
        // teardown in id order: block release order must not depend on
        // hash iteration order (bitwise-deterministic ticks)
        ended.sort_unstable();
        for (id, deadline) in ended {
            self.sched.remove(id);
            let Some(s) = self.seqs.remove(&id) else { continue };
            if let Some(ks) = s.backend.kv_stats() {
                self.metrics.dequant_rows += ks.dequant_rows;
            }
            let partial = Self::completion_of(id, &s, now);
            let reason = if deadline {
                self.metrics.deadline_missed += 1;
                FailReason::DeadlineExceeded(partial)
            } else {
                self.metrics.cancelled += 1;
                FailReason::Cancelled(partial)
            };
            s.send_event(Event::Failed(reason));
        }
    }

    fn completion_of(id: u64, s: &Sequence, end: Instant) -> Completion {
        Completion {
            id,
            tokens: s.response_tokens(),
            ttft_ms: s
                .first_token_at
                .map(|t| t.duration_since(s.arrived).as_secs_f64() * 1e3),
            total_ms: Some(end.duration_since(s.arrived).as_secs_f64() * 1e3),
            preemptions: s.preemptions,
            cached_prefix_tokens: s.cached_prefix,
        }
    }

    /// Execute one tick's decode work items.  With
    /// [`ServeConfig::batched_decode`], every batch-capable sequence
    /// sharing a model runs through ONE layer-major
    /// [`Model::decode_batch`] pass — logits bitwise-identical to the
    /// sequential path, weight reads amortized across the batch.
    /// Sequences with buffered prefill logits (no forward needed),
    /// non-batchable backends (PJRT, test doubles), and — on mixed
    /// ticks — sequences of a different model fall back sequentially.
    fn run_decodes(&mut self, ids: &[u64]) {
        if ids.is_empty() {
            return;
        }
        // analyze: allow(determinism) — decode-latency metric; never branches scheduling
        let t0 = Instant::now();
        let use_batch = self.sched.cfg.batched_decode;
        let metrics = &mut self.metrics;
        let scratch = &mut self.batch_scratch;
        let pool = self.pool.as_ref();
        let idset: HashSet<u64> = ids.iter().copied().collect();
        let mut by_id: HashMap<u64, &mut Sequence> = self
            // analyze: allow(determinism) — collected into a map; `ids` drives visit order
            .seqs
            .iter_mut()
            .filter(|(id, _)| idset.contains(id))
            .map(|(&id, s)| (id, s))
            .collect();
        let mut tokens_done = 0u64;
        let mut rest: Vec<&mut Sequence> = Vec::new();
        for id in ids {
            let s = match by_id.remove(id) {
                Some(s) => s,
                None => continue,
            };
            if use_batch && s.decode_input().is_some() && s.backend.batch_parts().is_some() {
                rest.push(s);
            } else {
                s.step_decode();
                tokens_done += 1;
            }
        }
        // group by shared model (Arc identity), one batched pass per group
        while !rest.is_empty() {
            let mut group: Vec<&mut Sequence> = Vec::new();
            let mut next: Vec<&mut Sequence> = Vec::new();
            let mut key: Option<*const Model> = None;
            for s in rest {
                let ptr = s.backend.batch_parts().map(|p| Arc::as_ptr(p.model));
                match (key, ptr) {
                    (None, Some(p)) => {
                        key = Some(p);
                        group.push(s);
                    }
                    (Some(kp), Some(p)) if p == kp => group.push(s),
                    (_, Some(_)) => next.push(s),
                    // backend stopped being batchable since the probe:
                    // decode it sequentially rather than panic/livelock
                    (_, None) => {
                        s.step_decode();
                        tokens_done += 1;
                    }
                }
            }
            rest = next;
            if group.is_empty() {
                continue;
            }
            let model: Arc<Model> = {
                // analyze: allow(panic-path) — probed batchable in the partition pass above
                let parts = group[0].backend.batch_parts().expect("probed batchable");
                parts.model.clone()
            };
            let mut reqs: Vec<DecodeReq> = Vec::with_capacity(group.len());
            for s in group.iter_mut() {
                // analyze: allow(panic-path) — decode_input() probed Some for every grouped seq
                let token = s.decode_input().expect("probed: logits not buffered");
                // analyze: allow(panic-path) — probed batchable in the partition pass above
                let parts = s.backend.batch_parts().expect("probed batchable");
                reqs.push(DecodeReq { token, st: parts.st, policy: parts.policy });
            }
            model.decode_batch(&mut reqs, scratch, pool);
            drop(reqs);
            metrics.decode_batch.add_us(group.len() as f64);
            for (j, s) in group.iter_mut().enumerate() {
                s.apply_decoded_logits(scratch.logits_row(j));
                tokens_done += 1;
            }
        }
        let dt_us = t0.elapsed().as_secs_f64() * 1e6;
        metrics.tokens_out += tokens_done;
        metrics.decode_tokens += tokens_done;
        metrics.decode_time_us += dt_us;
        if tokens_done > 0 {
            let per_tok = dt_us / tokens_done as f64;
            for _ in 0..tokens_done {
                metrics.tpot_us.add(per_tok);
                metrics.tpot_hist.add_us(per_tok);
            }
        }
    }

    /// After prefill work lands for `seq`, publish its newly completed
    /// full prompt blocks in the prefix index and store a backend state
    /// snapshot at the block-aligned boundary so later sequences with
    /// the same prefix can resume there.
    fn register_prefix(&mut self, seq: u64) {
        if !self.sched.cfg.enable_prefix_cache {
            return;
        }
        let s = match self.seqs.get(&seq) {
            Some(s) => s,
            None => return,
        };
        let done = match s.phase {
            SeqPhase::Prefilling { done } => done,
            SeqPhase::Decoding | SeqPhase::Finished => s.req.prompt.len(),
            SeqPhase::Waiting => return,
        };
        let bs = self.sched.cfg.block_size;
        let plen = s.req.prompt.len();
        // cap below the prompt end: the final token is always computed
        // fresh so the resumed sequence produces first-token logits
        let boundary = done.min(plen.saturating_sub(1)) / bs * bs;
        if boundary == 0 {
            return;
        }
        if let Some(hash) = self.sched.snapshot_wanted(seq, boundary) {
            if let Some(snap) = s.backend.fork_prefix(boundary) {
                self.sched.register_prefix(seq, boundary, true);
                if self.snapshots.insert(hash, snap).is_none() {
                    self.snapshot_order.push_back(hash);
                }
                while self.snapshots.len() > MAX_SNAPSHOTS {
                    let old = match self.snapshot_order.pop_front() {
                        Some(h) => h,
                        None => break,
                    };
                    if self.snapshots.remove(&old).is_some() {
                        self.sched.prefix.unmark_resumable(old);
                    }
                }
            }
        }
    }

    fn retire(&mut self) {
        let mut done_ids: Vec<u64> = self
            // analyze: allow(determinism) — pure filter; ids sorted before teardown
            .seqs
            .iter()
            .filter(|(_, s)| s.is_finished())
            .map(|(&id, _)| id)
            .collect();
        // retire in id order so event emission and block release are replayable
        done_ids.sort_unstable();
        for id in done_ids {
            self.sched.on_finished(id);
            let Some(s) = self.seqs.remove(&id) else { continue };
            if let Some(ks) = s.backend.kv_stats() {
                self.metrics.dequant_rows += ks.dequant_rows;
            }
            if let Some(t) = s.first_token_at {
                self.metrics
                    .ttft_us
                    .add_us(t.duration_since(s.arrived).as_secs_f64() * 1e6);
            }
            self.metrics.requests_done += 1;
            // analyze: allow(determinism) — completion timestamp for metrics only
            let end = s.finished_at.unwrap_or_else(Instant::now);
            let c = Self::completion_of(id, &s, end);
            s.send_event(Event::Done(c));
        }
    }

    /// Thin convenience wrapper over the streaming API: tick until every
    /// live sequence terminates, draining `handles` along the way.
    /// Returns the successful completions (a cancelled / expired /
    /// rejected handle contributes nothing here — read its `Failed`
    /// event via [`RequestHandle::try_next`] if you need the partial).
    pub fn run_to_completion(&mut self, handles: &mut [RequestHandle]) -> Vec<Completion> {
        let mut out = Vec::new();
        let mut guard = 0usize;
        while !self.idle() {
            let did = self.tick();
            guard = if did == 0 { guard + 1 } else { 0 };
            assert!(guard < 1000, "scheduler livelock: no work for 1000 ticks");
            for h in handles.iter_mut() {
                while let Some(ev) = h.try_next() {
                    if let Event::Done(c) = ev {
                        out.push(c);
                    }
                }
            }
        }
        out
    }

    /// Tear down EVERY live session as `Failed(Cancelled(partial))`,
    /// releasing all blocks — the abort path behind
    /// [`Server::stop_worker`], so stopping a worker never blocks on an
    /// unbounded in-flight request.
    pub fn cancel_all(&mut self) {
        // analyze: allow(determinism) — teardown timestamp for partial completions
        let now = Instant::now();
        // analyze: allow(determinism) — key snapshot; sorted before teardown
        let mut ids: Vec<u64> = self.seqs.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            self.sched.remove(id);
            let Some(s) = self.seqs.remove(&id) else { continue };
            if let Some(ks) = s.backend.kv_stats() {
                self.metrics.dequant_rows += ks.dequant_rows;
            }
            self.metrics.cancelled += 1;
            let partial = Self::completion_of(id, &s, now);
            s.send_event(Event::Failed(FailReason::Cancelled(partial)));
        }
    }

    /// Snapshot-store consistency: every held snapshot is still flagged
    /// resumable in the prefix index (no orphans the scheduler could
    /// never hand out), and the store respects its cap.  Meaningful
    /// after a tick has drained pending invalidations.
    pub fn check_snapshot_invariants(&self) -> Result<(), String> {
        if self.snapshots.len() > MAX_SNAPSHOTS {
            return Err(format!(
                "{} snapshots exceed the {MAX_SNAPSHOTS} cap",
                self.snapshots.len()
            ));
        }
        // analyze: allow(determinism) — read-only audit; any visit order gives the same verdict
        for h in self.snapshots.keys() {
            if !self.sched.prefix.is_resumable(*h) {
                return Err(format!("orphaned snapshot {h:#x}: not resumable in the index"));
            }
        }
        Ok(())
    }
}

enum Msg {
    Submit(u64, Request, Session),
    /// Graceful: drain the queue, finish in-flight work, exit.
    Shutdown,
    /// Immediate: fail every live session as `Cancelled`, exit.
    Abort,
}

/// Multi-worker server: router + one engine thread per worker.  All
/// workers share one handle-observed-TTFT collector (each worker's
/// returned metrics reports the server-wide histogram).
pub struct Server {
    router: Router,
    txs: Vec<Sender<Msg>>,
    handles: Vec<Option<std::thread::JoinHandle<ServeMetrics>>>,
    /// metrics of workers stopped before shutdown
    reaped: Vec<ServeMetrics>,
    streamed: Arc<Mutex<LatencyHist>>,
    next_id: u64,
}

impl Server {
    /// `factories` — one backend factory per worker.
    pub fn start(cfg: ServeConfig, factories: Vec<BackendFactory>) -> Self {
        let streamed: Arc<Mutex<LatencyHist>> = Arc::new(Mutex::new(LatencyHist::new()));
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for factory in factories {
            let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
            let cfg = cfg.clone();
            let streamed = streamed.clone();
            handles.push(Some(std::thread::spawn(move || {
                let mut engine = Engine::new(cfg, factory);
                engine.metrics.streamed_ttft_us = streamed;
                let mut open = true;
                loop {
                    // drain incoming without blocking while work remains
                    loop {
                        let msg = if engine.idle() && open {
                            rx.recv().ok()
                        } else {
                            match rx.try_recv() {
                                Ok(m) => Some(m),
                                Err(_) => None,
                            }
                        };
                        match msg {
                            Some(Msg::Submit(id, req, session)) => {
                                // rejections surface on the handle as
                                // Failed(Rejected(..)) — sent by submit_session
                                let _ = engine.submit_session(id, req, session);
                            }
                            Some(Msg::Shutdown) => open = false,
                            Some(Msg::Abort) => {
                                engine.cancel_all();
                                open = false;
                            }
                            None => break,
                        }
                    }
                    if engine.idle() {
                        if !open {
                            break;
                        }
                        continue;
                    }
                    engine.tick();
                }
                engine.metrics
            })));
            txs.push(tx);
        }
        Self {
            router: Router::new(txs.len()),
            txs,
            handles,
            reaped: Vec::new(),
            streamed,
            next_id: 0,
        }
    }

    /// Submit a request; events stream on the returned handle (block on
    /// [`RequestHandle::wait`]).  `session` pins worker affinity.  A dead
    /// worker is skipped and marked (subsequent affinity re-routes);
    /// `Err(SubmitError::WorkerDead)` only when no worker is alive.
    pub fn submit(
        &mut self,
        req: Request,
        session: Option<u64>,
    ) -> Result<RequestHandle, SubmitError> {
        let id = self.next_id;
        self.next_id += 1;
        let (handle, sess) = handle_pair(id, self.streamed.clone());
        let mut msg = Msg::Submit(id, req, sess);
        loop {
            let w = self.router.route(session).ok_or(SubmitError::WorkerDead)?;
            match self.txs[w].send(msg) {
                Ok(()) => return Ok(handle),
                Err(SendError(m)) => {
                    // the worker thread is gone: never route to it again
                    self.router.mark_dead(w);
                    self.reap(w);
                    msg = m;
                }
            }
        }
    }

    /// Stop one worker NOW: every queued and in-flight session on it
    /// fails with `Cancelled` (blocks released), the thread exits and is
    /// joined — bounded even with an unbounded request in flight.  The
    /// router routes around it from then on (session affinity re-probes
    /// to the next alive worker).  For a graceful full drain use
    /// [`Server::shutdown`].
    pub fn stop_worker(&mut self, w: usize) {
        if w >= self.txs.len() {
            return; // unknown worker id — nothing to stop
        }
        let _ = self.txs[w].send(Msg::Abort);
        self.router.mark_dead(w);
        self.reap(w);
    }

    pub fn alive_workers(&self) -> usize {
        self.router.alive_workers()
    }

    /// Total worker slots (alive + dead) — the stable id space
    /// [`Server::stop_worker`] addresses, used by the gateway registry
    /// to abort every worker of a replica it declares dead.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Server-wide handle-observed TTFT histogram.
    pub fn streamed_ttft(&self) -> LatencyHist {
        match self.streamed.lock() {
            Ok(h) => h.clone(),
            Err(_) => LatencyHist::new(),
        }
    }

    fn reap(&mut self, w: usize) {
        let Some(slot) = self.handles.get_mut(w) else { return };
        if let Some(h) = slot.take() {
            if let Ok(m) = h.join() {
                self.reaped.push(m);
            }
        }
    }

    /// Shut down and collect per-worker metrics (stopped workers included).
    pub fn shutdown(mut self) -> Vec<ServeMetrics> {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        let mut out = std::mem::take(&mut self.reaped);
        for h in self.handles.iter_mut() {
            if let Some(h) = h.take() {
                if let Ok(m) = h.join() {
                    out.push(m);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::test_backend::ToyBackend;
    use std::time::Duration;

    fn cfg() -> ServeConfig {
        ServeConfig {
            block_size: 16,
            num_blocks: 128,
            max_running: 4,
            token_budget: 128,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            ..ServeConfig::default()
        }
    }

    fn toy_factory() -> BackendFactory {
        Box::new(|_req| Box::new(ToyBackend::new(64)))
    }

    #[test]
    fn engine_completes_all_requests_with_streamed_events() {
        let mut e = Engine::new(cfg(), toy_factory());
        let mut handles = Vec::new();
        for id in 0..10u64 {
            let h = e
                .submit(Request::new(vec![0; 100 + 13 * id as usize]).max_new(5))
                .unwrap();
            assert_eq!(h.id(), id, "engine assigns monotonic ids");
            handles.push(h);
        }
        let done = e.run_to_completion(&mut handles);
        assert_eq!(done.len(), 10);
        for c in &done {
            assert_eq!(c.tokens.len(), 5);
            assert!(c.ttft_ms.is_some(), "tokens were emitted -> ttft present");
            assert!(c.total_ms.is_some());
        }
        assert_eq!(e.metrics.requests_done, 10);
        assert_eq!(e.metrics.tokens_out, 50);
        assert_eq!(
            e.metrics.streamed_ttft_us.lock().unwrap().count(),
            10,
            "every handle recorded a streamed TTFT"
        );
        e.sched.blocks.check_invariants().unwrap();
        assert_eq!(e.sched.blocks.used(), 0, "all blocks released");
    }

    #[test]
    fn engine_survives_memory_pressure_with_preemption() {
        let tight = ServeConfig { num_blocks: 12, max_running: 8, ..cfg() }; // 192 tokens
        let mut e = Engine::new(tight, toy_factory());
        let mut handles = Vec::new();
        for _ in 0..6 {
            handles.push(e.submit(Request::new(vec![0; 40]).max_new(30)).unwrap());
        }
        let done = e.run_to_completion(&mut handles);
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.tokens.len(), 30, "req {} emitted {}", c.id, c.tokens.len());
        }
        e.sched.blocks.check_invariants().unwrap();
    }

    #[test]
    fn typed_submit_errors() {
        let mut e = Engine::new(ServeConfig { queue_cap: 1, ..cfg() }, toy_factory());
        assert!(e.submit(Request::new(vec![0; 32])).is_ok());
        assert_eq!(
            e.submit(Request::new(vec![0; 32])).unwrap_err(),
            SubmitError::QueueFull
        );
        // pool is 128 blocks * 16 = 2048 tokens; a prompt that can never
        // also fit one decode token is rejected up front
        let mut e = Engine::new(cfg(), toy_factory());
        match e.submit(Request::new(vec![0; 4096])) {
            Err(SubmitError::PromptTooLong { prompt: 4096, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
        // explicit cap
        let mut e = Engine::new(
            ServeConfig { max_prompt_tokens: Some(50), ..cfg() },
            toy_factory(),
        );
        assert!(matches!(
            e.submit(Request::new(vec![0; 51])),
            Err(SubmitError::PromptTooLong { limit: 50, .. })
        ));
        assert!(e.submit(Request::new(vec![0; 50])).is_ok());
    }

    #[test]
    fn cancel_releases_blocks_within_one_tick() {
        let mut e = Engine::new(cfg(), toy_factory());
        let h = e.submit(Request::new(vec![0; 100]).max_new(1000)).unwrap();
        // into decode
        for _ in 0..4 {
            e.tick();
        }
        assert!(e.sched.blocks.used() > 0);
        h.cancel();
        e.tick();
        assert_eq!(e.sched.blocks.used(), 0, "cancel reclaims all blocks in one tick");
        assert_eq!(e.metrics.cancelled, 1);
        assert!(e.idle());
        e.sched.blocks.check_invariants().unwrap();
        let mut h = h;
        let mut failed = None;
        while let Some(ev) = h.try_next() {
            if let Event::Failed(f) = ev {
                failed = Some(f);
            }
        }
        match failed {
            Some(FailReason::Cancelled(partial)) => {
                assert!(!partial.tokens.is_empty(), "mid-decode cancel keeps the partial");
                assert!(partial.ttft_ms.is_some());
            }
            other => panic!("expected Cancelled, got {other:?}"),
        }
    }

    #[test]
    fn cancel_before_admission_reports_no_ttft() {
        // cancelled before the first tick: the request never leaves the
        // waiting queue and never emits a token
        let mut e = Engine::new(cfg(), toy_factory());
        let h = e.submit(Request::new(vec![0; 64]).max_new(4)).unwrap();
        h.cancel();
        e.tick();
        assert_eq!(e.metrics.cancelled, 1);
        let mut h = h;
        match h.wait(Duration::from_millis(100)) {
            Err(FailReason::Cancelled(partial)) => {
                assert!(partial.tokens.is_empty());
                assert!(partial.ttft_ms.is_none(), "no token -> no ttft, not 0.0");
                assert!(partial.total_ms.is_some());
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn deadline_expiry_fails_the_request() {
        let mut e = Engine::new(cfg(), toy_factory());
        let mut doomed = e
            .submit(Request::new(vec![0; 64]).max_new(1000).deadline_ms(0.0))
            .unwrap();
        let mut ok = e.submit(Request::new(vec![0; 64]).max_new(3)).unwrap();
        let mut guard = 0;
        while !e.idle() {
            e.tick();
            guard += 1;
            assert!(guard < 1000);
        }
        assert!(matches!(
            doomed.wait(Duration::from_millis(100)),
            Err(FailReason::DeadlineExceeded(_))
        ));
        assert_eq!(ok.wait(Duration::from_millis(100)).unwrap().tokens.len(), 3);
        assert_eq!(e.metrics.deadline_missed, 1);
        assert_eq!(e.sched.blocks.used(), 0);
    }

    /// Null-compute backend whose state is just a token count, with
    /// prefix-snapshot support — lets tests drive the snapshot/index
    /// machinery without a model.
    struct ForkableToy {
        tokens: usize,
    }

    impl SeqBackend for ForkableToy {
        fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
            self.tokens += tokens.len();
            Some(vec![0.0, 1.0])
        }

        fn decode(&mut self, _token: u32) -> Vec<f32> {
            self.tokens += 1;
            vec![0.0, 1.0]
        }

        fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
            if tokens > self.tokens {
                return None;
            }
            Some(Box::new(ForkableToy { tokens }))
        }
    }

    /// `snapshot_order` used to accumulate one dead hash per boundary
    /// whose snapshot was pruned by index invalidation (block eviction
    /// under pressure) — unbounded growth under churn.  The tick loop
    /// now compacts stale entries; this churns hundreds of distinct
    /// prompts through a tiny pool and asserts the queue stays
    /// proportional to the live snapshot count.
    #[test]
    fn snapshot_order_stays_bounded_under_invalidation_churn() {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 16, // 256 tokens: constant eviction pressure
            max_running: 2,
            token_budget: 256,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 16,
            ..ServeConfig::default()
        };
        let mut e = Engine::new(cfg, Box::new(|_req| Box::new(ForkableToy { tokens: 0 })));
        for id in 0..600u64 {
            // distinct prompts: every admission registers fresh boundaries
            // and evicts someone else's blocks (invalidating their hashes)
            let prompt: Vec<u32> = (0..64).map(|j| (id * 64 + j) as u32).collect();
            let mut h = vec![e.submit(Request::new(prompt).max_new(2)).unwrap()];
            e.run_to_completion(&mut h);
        }
        assert!(
            // threshold + a tick's worth of registrations (compaction
            // runs at the START of the next tick)
            e.snapshot_order.len() <= 2 * e.snapshots.len().max(32) + 8,
            "snapshot_order grew to {} with only {} live snapshots",
            e.snapshot_order.len(),
            e.snapshots.len()
        );
        e.sched.blocks.check_invariants().unwrap();
        e.tick(); // drain pending invalidations, then audit the store
        e.check_snapshot_invariants().unwrap();
    }

    #[test]
    fn server_round_trips_across_workers() {
        let mut srv = Server::start(cfg(), vec![toy_factory(), toy_factory()]);
        let mut handles = Vec::new();
        for id in 0..8u64 {
            handles.push(
                srv.submit(Request::new(vec![0; 64]).max_new(3), Some(id % 3))
                    .unwrap(),
            );
        }
        for h in &mut handles {
            let c = h.wait(Duration::from_secs(30)).unwrap();
            assert_eq!(c.tokens.len(), 3);
        }
        assert!(srv.streamed_ttft().count() >= 8, "handles recorded streamed TTFT");
        let metrics = srv.shutdown();
        let total: u64 = metrics.iter().map(|m| m.requests_done).sum();
        assert_eq!(total, 8);
    }

    /// `stop_worker` must return promptly even with an effectively
    /// unbounded request in flight — the session fails as `Cancelled`
    /// instead of the stopping thread blocking on a ~1M-tick drain.
    #[test]
    fn stop_worker_aborts_unbounded_inflight_sessions() {
        let mut srv = Server::start(cfg(), vec![toy_factory()]);
        let mut h = srv
            .submit(Request::new(vec![0; 64]).max_new(1_000_000), None)
            .unwrap();
        // wait until it demonstrably runs
        assert!(h.next_timeout(Duration::from_secs(30)).is_some());
        srv.stop_worker(0);
        match h.wait(Duration::from_secs(30)) {
            Err(FailReason::Cancelled(_)) => {}
            other => panic!("expected Cancelled on abort, got {other:?}"),
        }
        let metrics = srv.shutdown();
        assert_eq!(metrics.iter().map(|m| m.cancelled).sum::<u64>(), 1);
    }

    #[test]
    fn dead_worker_is_skipped_and_requests_complete() {
        let mut srv = Server::start(cfg(), vec![toy_factory(), toy_factory()]);
        srv.stop_worker(0);
        assert_eq!(srv.alive_workers(), 1);
        let mut handles = Vec::new();
        for s in 0..6u64 {
            // sessions that would have hashed to either worker all land
            // on the survivor — no panic, no lost requests
            handles.push(srv.submit(Request::new(vec![0; 32]).max_new(2), Some(s)).unwrap());
        }
        for h in &mut handles {
            assert_eq!(h.wait(Duration::from_secs(30)).unwrap().tokens.len(), 2);
        }
        let metrics = srv.shutdown();
        let total: u64 = metrics.iter().map(|m| m.requests_done).sum();
        assert_eq!(total, 6);
    }
}
