//! Table regenerators (Tables 1-3).

use super::common::{category_tasks, dense_prefill, run_task, EvalCtx, StrategyKind};
use crate::attention::{self, AttnScratch, CostTracker, IndexSet, KvCache};
use crate::config::TopKRule;
use crate::kascade::LayerRole;
use crate::stats::Timer;
use crate::tensor::Rng;
use crate::workload::{Category, WorkloadGen};

/// Table 1: LongBench-S — 6 categories x strategies, Top-k 10%.
pub fn table1_longbench(ctx: &EvalCtx) -> anyhow::Result<()> {
    let rule = TopKRule::new(0.10, 128);
    println!("Table 1 — LongBench-S accuracy (Top-k 10%, min 128; ctx {})", ctx.ctx_len());
    let mut rows = Vec::new();
    for v in &ctx.variants {
        println!("\n**{}**", v.name);
        println!("| Strategy | SQA | MQA | Summ. | Fewshot | Synthetic | Code | Avg. |");
        println!("|---|---|---|---|---|---|---|---|");
        // tasks per category (shared across strategies)
        let cats: Vec<(Category, Vec<crate::workload::Task>)> = Category::ALL
            .iter()
            .map(|&c| (c, category_tasks(&v.spec, c, ctx.n_prompts(), ctx.ctx_len(), 0x7AB1)))
            .collect();
        // shared dense prefills per task
        let mut shared: Vec<Vec<(crate::model::SeqState, Vec<f32>)>> = Vec::new();
        for (_, tasks) in &cats {
            shared.push(tasks.iter().map(|t| dense_prefill(&v.model, t)).collect());
        }
        for strat in StrategyKind::TABLE {
            let mut accs = Vec::new();
            for (ci, (_, tasks)) in cats.iter().enumerate() {
                let mut correct = 0.0;
                for (ti, t) in tasks.iter().enumerate() {
                    let (st, lg) = &shared[ci][ti];
                    let use_shared = !strat.sparse_prefill();
                    let o = run_task(
                        &v.model,
                        t,
                        strat,
                        &v.cal.plan,
                        rule,
                        use_shared.then_some(st),
                        use_shared.then_some(lg),
                    );
                    correct += o.correct as u8 as f64;
                }
                accs.push(100.0 * correct / tasks.len() as f64);
            }
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            println!(
                "| {} | {} | {avg:.1} |",
                strat.name(),
                accs.iter().map(|a| format!("{a:.1}")).collect::<Vec<_>>().join(" | ")
            );
            rows.push(format!(
                "{},{},{},{avg:.2}",
                v.name,
                strat.name(),
                accs.iter().map(|a| format!("{a:.2}")).collect::<Vec<_>>().join(",")
            ));
        }
    }
    ctx.write_csv(
        "table1_longbench",
        "model,strategy,sqa,mqa,summ,fewshot,synthetic,code,avg",
        &rows,
    )
}

/// Table 2: AIME-S — pass@1 + decode length, Top-k 10%.
pub fn table2_aime(ctx: &EvalCtx) -> anyhow::Result<()> {
    let rule = TopKRule::new(0.10, 128);
    let hops = if ctx.opts.fast { 4 } else { 8 };
    println!("Table 2 — AIME-S pass@1 (decode length), Top-k 10%, {hops}-hop chains");
    println!("| Strategy | {} |", ctx.variants.iter().map(|v| v.name).collect::<Vec<_>>().join(" | "));
    println!("|---|{}|", "---|".repeat(ctx.variants.len()));
    let mut cells: Vec<Vec<String>> = vec![Vec::new(); StrategyKind::TABLE.len()];
    let mut rows = Vec::new();
    for v in &ctx.variants {
        let mut gen = WorkloadGen::new(&v.spec, 0x7AB2);
        let tasks: Vec<_> = (0..ctx.n_prompts()).map(|_| gen.aime(ctx.ctx_len(), hops)).collect();
        let shared: Vec<_> = tasks.iter().map(|t| dense_prefill(&v.model, t)).collect();
        for (si, strat) in StrategyKind::TABLE.iter().enumerate() {
            let mut correct = 0.0;
            let mut dl = 0.0;
            for (ti, t) in tasks.iter().enumerate() {
                let (st, lg) = &shared[ti];
                let use_shared = !strat.sparse_prefill();
                let o = run_task(
                    &v.model,
                    t,
                    *strat,
                    &v.cal.plan,
                    rule,
                    use_shared.then_some(st),
                    use_shared.then_some(lg),
                );
                correct += o.correct as u8 as f64;
                dl += o.decode_len as f64;
            }
            let n = tasks.len() as f64;
            cells[si].push(format!("{:.1} ({:.1})", 100.0 * correct / n, dl / n));
            rows.push(format!(
                "{},{},{:.2},{:.2}",
                v.name,
                strat.name(),
                100.0 * correct / n,
                dl / n
            ));
        }
    }
    for (si, strat) in StrategyKind::TABLE.iter().enumerate() {
        println!("| {} | {} |", strat.name(), cells[si].join(" | "));
    }
    ctx.write_csv("table2_aime", "model,strategy,pass1,decode_len", &rows)
}

/// One attention-op timing sample on random KV state.
fn time_decode_op(
    cache: &KvCache,
    q: &[f32],
    g: usize,
    role: Option<LayerRole>,
    k: usize,
    reps: usize,
) -> f64 {
    let n_q = cache.n_kv * g;
    let d = cache.d;
    let mut out = vec![0.0f32; n_q * d];
    let mut cost = CostTracker::default();
    let mut scratch = AttnScratch::new();
    // fixed index set for reuse timing (cost is shape-, not value-dependent)
    let fixed = IndexSet::from_nested(
        &(0..cache.n_kv)
            .map(|h| (0..k as u32).map(|i| (i * 7 + h as u32) % cache.len as u32).collect())
            .collect::<Vec<Vec<u32>>>(),
    );
    let t = Timer::start();
    for _ in 0..reps {
        match role {
            None => attention::decode_dense(q, cache, g, &mut out, &mut scratch.planes, &mut cost),
            Some(LayerRole::Anchor0) => {
                // dense output + pooled scores + top-k
                attention::decode_dense(q, cache, g, &mut out, &mut scratch.planes, &mut cost);
                attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, &mut cost);
                attention::select_topk(&mut scratch, k, &mut cost);
            }
            Some(LayerRole::Anchor) => {
                attention::decode_pooled_scores(q, cache, g, &mut scratch.planes, &mut cost);
                attention::select_topk(&mut scratch, k, &mut cost);
                let AttnScratch { sel, planes } = &mut scratch;
                attention::decode_sparse(q, cache, g, sel, &mut out, planes, &mut cost);
            }
            Some(LayerRole::Reuse { .. }) => {
                let planes = &mut scratch.planes;
                attention::decode_sparse(q, cache, g, &fixed, &mut out, planes, &mut cost);
            }
        }
    }
    t.us() / reps as f64
}

fn time_prefill_tile(
    cache: &KvCache,
    qs: &[f32],
    start: usize,
    g: usize,
    role: Option<LayerRole>,
    k: usize,
) -> f64 {
    let n_q = cache.n_kv * g;
    let d = cache.d;
    let tile = qs.len() / (n_q * d);
    let mut out = vec![0.0f32; tile * n_q * d];
    let mut cost = CostTracker::default();
    let mut scratch = AttnScratch::new();
    let fixed = IndexSet::from_nested(
        &(0..cache.n_kv)
            .map(|h| (0..k as u32).map(|i| (i * 13 + h as u32) % (start + 1) as u32).collect())
            .collect::<Vec<Vec<u32>>>(),
    );
    let t = Timer::start();
    match role {
        None => {
            let planes = &mut scratch.planes;
            attention::prefill_dense_tile(qs, start, cache, g, &mut out, planes, &mut cost)
        }
        Some(LayerRole::Anchor0) => {
            let planes = &mut scratch.planes;
            attention::prefill_dense_tile(qs, start, cache, g, &mut out, planes, &mut cost);
            attention::prefill_pooled_scores(qs, start, cache, g, &mut scratch.planes, &mut cost);
            attention::select_topk(&mut scratch, k, &mut cost);
        }
        Some(LayerRole::Anchor) => {
            attention::prefill_pooled_scores(qs, start, cache, g, &mut scratch.planes, &mut cost);
            attention::select_topk(&mut scratch, k, &mut cost);
            let AttnScratch { sel, planes } = &mut scratch;
            attention::prefill_sparse_tile(qs, start, cache, g, sel, &mut out, planes, &mut cost);
        }
        Some(LayerRole::Reuse { .. }) => {
            let planes = &mut scratch.planes;
            attention::prefill_sparse_tile(qs, start, cache, g, &fixed, &mut out, planes, &mut cost);
        }
    }
    t.us()
}

/// Table 3: decode + prefill attention speedups vs dense across context
/// lengths and Top-k %.  Kascade time = weighted mix of anchor0 / anchor /
/// reuse layer costs (paper Table 3 caption: weights 1/L, (A-1)/L,
/// (L-A)/L).
pub fn table3_kernels(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let cfg = &v.spec.cfg;
    let (n_kv, g, d) = (cfg.n_kv_heads, cfg.group(), cfg.d_head);
    let n_layers = cfg.n_layers as f64;
    let n_anchors = v.cal.plan.anchors.len() as f64;
    let mut rng = Rng::new(3);

    let decode_ctx: Vec<usize> = if ctx.opts.fast {
        vec![8192, 16384, 32768]
    } else {
        vec![8192, 16384, 32768, 65536, 131072]
    };
    let fracs = [0.05f32, 0.10, 0.15, 0.20, 0.25, 0.30];

    println!("Table 3 — attention speedup vs dense (native engine, 1 CPU core)");
    println!("Kascade time = (1/L)*anchor0 + ((A-1)/L)*anchor + ((L-A)/L)*reuse, L={n_layers}, A={n_anchors}");
    println!("\n**decode**");
    println!("| ctx | {} |", fracs.iter().map(|f| format!("k={:.0}%", f * 100.0)).collect::<Vec<_>>().join(" | "));
    println!("|---|{}|", "---|".repeat(fracs.len()));
    let mut rows = Vec::new();
    for &len in &decode_ctx {
        let mut cache = KvCache::new(n_kv, d, len);
        let mut kbuf = vec![0.0f32; n_kv * d];
        let mut vbuf = vec![0.0f32; n_kv * d];
        for _ in 0..len {
            rng.fill_normal(&mut kbuf, 0.5);
            rng.fill_normal(&mut vbuf, 1.0);
            cache.push(&kbuf, &vbuf);
        }
        let mut q = vec![0.0f32; n_kv * g * d];
        rng.fill_normal(&mut q, 1.0);
        let reps = (2_000_000 / len).clamp(1, 50);
        let dense = time_decode_op(&cache, &q, g, None, 128, reps);
        let mut cells = Vec::new();
        for &f in &fracs {
            let k = TopKRule::new(f, 128).k(len);
            let a0 = time_decode_op(&cache, &q, g, Some(LayerRole::Anchor0), k, reps);
            let an = time_decode_op(&cache, &q, g, Some(LayerRole::Anchor), k, reps);
            let ru = time_decode_op(&cache, &q, g, Some(LayerRole::Reuse { anchor: 0 }), k, reps);
            let kas = (a0 + (n_anchors - 1.0) * an + (n_layers - n_anchors) * ru) / n_layers;
            let speedup = dense / kas;
            cells.push(format!("{speedup:.2}"));
            rows.push(format!("decode,{len},{f},{dense:.1},{kas:.1},{speedup:.3}"));
        }
        println!("| {len} | {} |", cells.join(" | "));
    }

    println!("\n**prefill** (per 128-query tile at the context frontier)");
    let prefill_ctx: Vec<usize> = if ctx.opts.fast { vec![4096, 8192] } else { vec![4096, 8192, 16384, 32768] };
    println!("| ctx | {} |", fracs.iter().map(|f| format!("k={:.0}%", f * 100.0)).collect::<Vec<_>>().join(" | "));
    println!("|---|{}|", "---|".repeat(fracs.len()));
    for &len in &prefill_ctx {
        let mut cache = KvCache::new(n_kv, d, len);
        let mut kbuf = vec![0.0f32; n_kv * d];
        let mut vbuf = vec![0.0f32; n_kv * d];
        for _ in 0..len {
            rng.fill_normal(&mut kbuf, 0.5);
            rng.fill_normal(&mut vbuf, 1.0);
            cache.push(&kbuf, &vbuf);
        }
        let tile = 128;
        let start = len - tile;
        let mut qs = vec![0.0f32; tile * n_kv * g * d];
        rng.fill_normal(&mut qs, 1.0);
        let dense = time_prefill_tile(&cache, &qs, start, g, None, 128);
        let mut cells = Vec::new();
        for &f in &fracs {
            let k = TopKRule::new(f, 128).k(len);
            let a0 = time_prefill_tile(&cache, &qs, start, g, Some(LayerRole::Anchor0), k);
            let an = time_prefill_tile(&cache, &qs, start, g, Some(LayerRole::Anchor), k);
            let ru = time_prefill_tile(&cache, &qs, start, g, Some(LayerRole::Reuse { anchor: 0 }), k);
            let kas = (a0 + (n_anchors - 1.0) * an + (n_layers - n_anchors) * ru) / n_layers;
            let speedup = dense / kas;
            cells.push(format!("{speedup:.2}"));
            rows.push(format!("prefill,{len},{f},{dense:.1},{kas:.1},{speedup:.3}"));
        }
        println!("| {len} | {} |", cells.join(" | "));
    }
    ctx.write_csv(
        "table3_kernels",
        "phase,ctx,frac,dense_us,kascade_us,speedup",
        &rows,
    )
}
