//! Shared evaluation scaffolding: models, calibration, strategy zoo,
//! shared-prefill task runner, CSV/markdown output.

use crate::config::TopKRule;
use crate::kascade::{calibrate, CalibrateOptions, Calibration, KascadePlan};
use crate::model::{Model, SynthSpec};
use crate::sparse::{
    DensePolicy, KascadeAllPooledPolicy, KascadePolicy, LessIsMorePolicy, OmniKvPolicy,
    OraclePolicy, QuestPolicy, SparsePolicy, StreamingLlmPolicy,
};
use crate::workload::{grade, Category, Task, WorkloadGen};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;

#[derive(Debug, Clone)]
pub struct EvalOptions {
    /// Fast mode: fewer prompts, shorter contexts (CI-friendly).
    pub fast: bool,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for EvalOptions {
    fn default() -> Self {
        Self { fast: false, out_dir: PathBuf::from("results"), seed: 42 }
    }
}

/// A calibrated model variant (the stand-in for "Llama-3.1-8B" etc.).
pub struct ModelVariant {
    pub name: &'static str,
    pub spec: SynthSpec,
    pub model: Model,
    pub cal: Calibration,
}

/// Everything the drivers need.
pub struct EvalCtx {
    pub opts: EvalOptions,
    pub variants: Vec<ModelVariant>,
}

impl EvalCtx {
    pub fn new(opts: &EvalOptions) -> Self {
        // Variant A mirrors Llama-3.1-8B-Instruct in the tables; variant B
        // (different seed + block structure) plays the Qwen3-8B role.
        let mut spec_a = SynthSpec::eval_base(opts.seed);
        spec_a.block_starts = vec![1, 4, 8, 12];
        let mut spec_b = SynthSpec::eval_base(opts.seed ^ 0xB0B);
        spec_b.block_starts = vec![1, 3, 7, 11];
        spec_b.out_decay = 0.7;
        let variants = vec![("SynthLM-A", spec_a), ("SynthLM-B", spec_b)]
            .into_iter()
            .map(|(name, spec)| {
                let model = spec.build();
                let ctx = if opts.fast { 768 } else { 1536 };
                let mut gen = WorkloadGen::new(&spec, 0xDE5); // dev != eval seeds
                let prompts: Vec<Vec<u32>> =
                    (0..if opts.fast { 2 } else { 4 }).map(|_| gen.dev_prompt(ctx)).collect();
                let cal = calibrate(&model, &prompts, &CalibrateOptions::default());
                eprintln!(
                    "[calibrated {name}] anchors={:?} objective={:.3}",
                    cal.plan.anchors, cal.plan.objective
                );
                ModelVariant { name, spec, model, cal }
            })
            .collect();
        Self { opts: opts.clone(), variants }
    }

    pub fn ctx_len(&self) -> usize {
        if self.opts.fast { 1024 } else { 2048 }
    }

    pub fn n_prompts(&self) -> usize {
        if self.opts.fast { 3 } else { 6 }
    }

    pub fn write_csv(&self, name: &str, header: &str, rows: &[String]) -> anyhow::Result<()> {
        let path = self.opts.out_dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{header}")?;
        for r in rows {
            writeln!(f, "{r}")?;
        }
        eprintln!("[wrote {}]", path.display());
        Ok(())
    }
}

/// The strategy zoo of Tables 1-2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Dense,
    StreamingLlm,
    LessIsMore,
    OmniKv,
    Quest,
    Kascade,
    KascadeAllPooled,
    Oracle,
}

impl StrategyKind {
    pub const TABLE: [StrategyKind; 7] = [
        StrategyKind::Dense,
        StrategyKind::StreamingLlm,
        StrategyKind::LessIsMore,
        StrategyKind::OmniKv,
        StrategyKind::Quest,
        StrategyKind::Kascade,
        StrategyKind::KascadeAllPooled,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Dense => "Baseline (Dense)",
            StrategyKind::StreamingLlm => "StreamingLLM",
            StrategyKind::LessIsMore => "LessIsMore (decode-only)",
            StrategyKind::OmniKv => "OmniKV (decode-only)",
            StrategyKind::Quest => "Quest (decode-only)",
            StrategyKind::Kascade => "Kascade",
            StrategyKind::KascadeAllPooled => "Kascade (All Heads Pooled)",
            StrategyKind::Oracle => "Oracle Top-k",
        }
    }

    /// Whether this strategy sparsifies the prefill (otherwise the runner
    /// shares one dense prefill across strategies, as the paper notes).
    pub fn sparse_prefill(&self) -> bool {
        matches!(
            self,
            StrategyKind::Kascade | StrategyKind::KascadeAllPooled | StrategyKind::StreamingLlm | StrategyKind::Oracle
        )
    }

    pub fn build(&self, plan: &KascadePlan, rule: TopKRule, n_layers: usize) -> Box<dyn SparsePolicy> {
        match self {
            StrategyKind::Dense => Box::new(DensePolicy),
            StrategyKind::StreamingLlm => Box::new(StreamingLlmPolicy::paper_default()),
            StrategyKind::LessIsMore => {
                // manual layer choice (no automation — the paper's point):
                // evenly spaced, same count as the plan's anchors
                let m = plan.anchors.len().max(2);
                let layers: Vec<usize> =
                    (1..m).map(|i| 1 + (i - 1) * (n_layers - 1) / (m - 1)).collect();
                Box::new(LessIsMorePolicy::new(n_layers, layers, rule))
            }
            StrategyKind::OmniKv => {
                let layers = vec![1, n_layers / 3, 2 * n_layers / 3];
                Box::new(OmniKvPolicy::new(n_layers, layers, rule))
            }
            StrategyKind::Quest => Box::new(QuestPolicy::new(rule)),
            StrategyKind::Kascade => {
                let mut p = plan.clone();
                p.topk = rule;
                Box::new(KascadePolicy::new(p))
            }
            StrategyKind::KascadeAllPooled => {
                let mut p = plan.clone();
                p.topk = rule;
                Box::new(KascadeAllPooledPolicy::new(p))
            }
            StrategyKind::Oracle => Box::new(OraclePolicy::new(rule)),
        }
    }
}

/// Outcome of one task under one strategy.
#[derive(Debug, Clone, Copy)]
pub struct TaskOutcome {
    pub correct: bool,
    pub decode_len: usize,
    /// attention key-reads per generated token (work proxy)
    pub key_reads_per_tok: f64,
}

/// Run `task` under `strategy`, optionally reusing a shared dense-prefill
/// state (decode-only strategies).
pub fn run_task(
    model: &Model,
    task: &Task,
    strategy: StrategyKind,
    plan: &KascadePlan,
    rule: TopKRule,
    shared_dense: Option<&crate::model::SeqState>,
    shared_logits: Option<&Vec<f32>>,
) -> TaskOutcome {
    let lay_vocab = model.cfg.vocab; // stop on TERM value token via closure below
    let _ = lay_vocab;
    let mut policy = strategy.build(plan, rule, model.cfg.n_layers);
    let (mut st, logits) = match (strategy.sparse_prefill(), shared_dense, shared_logits) {
        (false, Some(st), Some(lg)) => (st.clone(), lg.clone()),
        _ => {
            let mut st = model.new_state(task.prompt.len() + task.max_new + 8);
            let (lg, _) = model.prefill(&task.prompt, &mut st, policy.as_mut(), None);
            (st, lg)
        }
    };
    let base_reads = st.cost.attend_kv_reads + st.cost.score_key_reads;
    let stop_tok = *task.expect.last().unwrap();
    let emitted = model.greedy_decode(&logits, &mut st, policy.as_mut(), task.max_new, |t| {
        t == stop_tok
    });
    let reads = (st.cost.attend_kv_reads + st.cost.score_key_reads) - base_reads;
    TaskOutcome {
        correct: grade(task, &emitted),
        decode_len: emitted.len(),
        key_reads_per_tok: reads as f64 / emitted.len().max(1) as f64,
    }
}

/// Dense prefill shared across decode-only strategies.
pub fn dense_prefill(model: &Model, task: &Task) -> (crate::model::SeqState, Vec<f32>) {
    let mut st = model.new_state(task.prompt.len() + task.max_new + 8);
    let (lg, _) = model.prefill(&task.prompt, &mut st, &mut DensePolicy, None);
    (st, lg)
}

/// Accuracy aggregation helper.
#[derive(Default)]
pub struct Agg {
    pub per_key: BTreeMap<String, (f64, f64, usize)>, // sum_correct, sum_declen, n
}

impl Agg {
    pub fn add(&mut self, key: String, o: &TaskOutcome) {
        let e = self.per_key.entry(key).or_insert((0.0, 0.0, 0));
        e.0 += o.correct as u8 as f64;
        e.1 += o.decode_len as f64;
        e.2 += 1;
    }

    pub fn acc(&self, key: &str) -> f64 {
        self.per_key.get(key).map(|(c, _, n)| 100.0 * c / *n as f64).unwrap_or(f64::NAN)
    }

    pub fn decode_len(&self, key: &str) -> f64 {
        self.per_key.get(key).map(|(_, d, n)| d / *n as f64).unwrap_or(f64::NAN)
    }
}

/// Build the evaluation tasks for one category.
pub fn category_tasks(
    spec: &SynthSpec,
    cat: Category,
    n: usize,
    ctx: usize,
    seed: u64,
) -> Vec<Task> {
    let mut gen = WorkloadGen::new(spec, seed ^ cat.name().len() as u64);
    (0..n).map(|_| gen.longbench(cat, ctx)).collect()
}
