//! Figure regenerators (Figs. 1-7).  Fig. 8 is a bench target
//! (`cargo bench --bench fig8_pass_split`).

use super::common::{category_tasks, dense_prefill, run_task, EvalCtx, StrategyKind};


use crate::config::TopKRule;
use crate::model::{CaptureRequest, VocabLayout};
use crate::sparse::DensePolicy;
use crate::tensor::topk_indices;
use crate::workload::{Category, WorkloadGen};

/// Fig. 1: attention mass covered by the top-256 keys, per layer x head.
pub fn fig1_topk_mass(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let mut gen = WorkloadGen::new(&v.spec, 0xF16 + 1);
    let prompt = gen.dev_prompt(ctx.ctx_len());
    let probe = prompt.len() - 1;
    let mut st = v.model.new_state(prompt.len() + 4);
    let (_, cap) = v.model.prefill(
        &prompt,
        &mut st,
        &mut DensePolicy,
        Some(&CaptureRequest { probe_positions: vec![probe] }),
    );
    let cap = cap.unwrap();
    let k = 256;
    println!("Fig 1 — attention mass of top-{k} keys (ctx {}), layer x kv-head", probe + 1);
    println!("model={}  (paper: >=95% everywhere except layer 0)", v.name);
    let mut rows = Vec::new();
    println!("| layer | {} |", (0..v.spec.cfg.n_kv_heads).map(|h| format!("head {h}")).collect::<Vec<_>>().join(" | "));
    println!("|---|{}|", "---|".repeat(v.spec.cfg.n_kv_heads));
    for (l, dists) in cap.probes[0].dists.iter().enumerate() {
        let masses: Vec<f64> = dists
            .iter()
            .map(|d| {
                topk_indices(d, k.min(d.len()))
                    .iter()
                    .map(|&i| d[i as usize] as f64)
                    .sum::<f64>()
            })
            .collect();
        println!(
            "| {l} | {} |",
            masses.iter().map(|m| format!("{:.3}", m)).collect::<Vec<_>>().join(" | ")
        );
        rows.push(format!(
            "{l},{}",
            masses.iter().map(|m| format!("{m:.4}")).collect::<Vec<_>>().join(",")
        ));
    }
    ctx.write_csv("fig1_topk_mass", "layer,head0,head1,head2,head3", &rows)
}

/// Fig. 2: Oracle Top-k task accuracy vs k/N (layer 0 dense).
pub fn fig2_oracle_sweep(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let tasks = category_tasks(&v.spec, Category::Sqa, ctx.n_prompts(), ctx.ctx_len(), 0xF2);
    let fracs = [0.01, 0.025, 0.05, 0.10, 0.20, 1.0];
    println!("Fig 2 — Oracle Top-k accuracy vs k/N (model={}, SQA, ctx {})", v.name, ctx.ctx_len());
    println!("| k/N | accuracy % |");
    println!("|---|---|");
    let mut rows = Vec::new();
    for &f in &fracs {
        let rule = TopKRule::new(f as f32, 1); // pure percentage, no floor
        let mut correct = 0usize;
        for t in &tasks {
            let o = run_task(&v.model, t, StrategyKind::Oracle, &v.cal.plan, rule, None, None);
            correct += o.correct as usize;
        }
        let acc = 100.0 * correct as f64 / tasks.len() as f64;
        println!("| {:.1}% | {acc:.1} |", f * 100.0);
        rows.push(format!("{f},{acc:.2}"));
    }
    ctx.write_csv("fig2_oracle_sweep", "frac,accuracy", &rows)
}

/// Fig. 3: cross-layer similarity matrix (Eq. 3).
pub fn fig3_similarity(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let m = v.cal.sim.layer_matrix(false);
    let nl = v.spec.cfg.n_layers;
    println!("Fig 3 — cross-layer similarity (sim_k={}, model={})", v.cal.sim.k, v.name);
    println!("(planted blocks at {:?}; bright diagonal bands expected)", v.spec.block_starts);
    let mut rows = Vec::new();
    for a in 0..nl {
        let cells: Vec<String> = (0..nl)
            .map(|b| if b >= a { format!("{:.2}", m.get(a, b)) } else { "    ".into() })
            .collect();
        println!("L{a:>2}: {}", cells.join(" "));
        rows.push(format!(
            "{a},{}",
            (0..nl).map(|b| format!("{:.4}", m.get(a.min(b), a.max(b)))).collect::<Vec<_>>().join(",")
        ));
    }
    let hdr = format!("layer,{}", (0..nl).map(|b| format!("l{b}")).collect::<Vec<_>>().join(","));
    ctx.write_csv("fig3_similarity", &hdr, &rows)
}

/// Fig. 4: per-layer attention importance `w_l = 1 - cos(x, y)`.
pub fn fig4_importance(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    println!("Fig 4 — layer importance (model={}; paper: sharp decay with depth)", v.name);
    println!("| layer | importance | normalized |");
    println!("|---|---|---|");
    let max = v.cal.importance.iter().cloned().fold(f32::MIN, f32::max).max(1e-12);
    let mut rows = Vec::new();
    for (l, &w) in v.cal.importance.iter().enumerate() {
        println!("| {l} | {w:.5} | {:.3} |", w / max);
        rows.push(format!("{l},{w},{}", w / max));
    }
    ctx.write_csv("fig4_importance", "layer,importance,normalized", &rows)
}

/// Fig. 5: pre- vs post-softmax pooling recovery across tile sizes.
pub fn fig5_pooling(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let cfg = &v.spec.cfg;
    let (g, d) = (cfg.group(), cfg.d_head);
    let layer = v.spec.block_starts[0]; // a match layer
    let mut gen = WorkloadGen::new(&v.spec, 0xF5);
    let ctx_len = ctx.ctx_len().min(1024);
    let k_frac = 0.10;

    // average recovery over prompts: fraction of each query's own top-k
    // mass captured by the tile-pooled index set
    let tiles = [4usize, 8, 16, 32, 64, 128];
    let mut post_r = vec![0.0f64; tiles.len()];
    let mut pre_r = vec![0.0f64; tiles.len()];
    let n_prompts = ctx.n_prompts().min(4);
    for p in 0..n_prompts {
        let _ = p;
        let prompt = gen.dev_prompt(ctx_len);
        let (qs, cache) = v.model.capture_layer_qk(&prompt, layer);
        let t = cache.len;
        let k = ((k_frac * t as f64) as usize).max(8);
        let nqd = cfg.n_q_heads * d;
        let last = t - 128; // final full tile
        // per-query-head causal distributions for the final 128 queries,
        // zero-padded to length t: [128][n_q][t]
        let per_q: Vec<Vec<Vec<f32>>> = (last..t)
            .map(|qpos| {
                (0..cfg.n_q_heads)
                    .map(|hq| {
                        let h = hq / g;
                        let qrow = &qs[qpos * nqd + hq * d..qpos * nqd + (hq + 1) * d];
                        let mut s = vec![0.0f32; qpos + 1];
                        for p in 0..=qpos {
                            s[p] = crate::tensor::dot(qrow, cache.key(h, p)) / (d as f32).sqrt();
                        }
                        crate::tensor::softmax(&mut s);
                        s.resize(t, 0.0);
                        s
                    })
                    .collect()
            })
            .collect();
        for (ti, &tile) in tiles.iter().enumerate() {
            let mut post_sum = 0.0f64;
            let mut pre_sum = 0.0f64;
            let mut cnt = 0usize;
            for t0 in (0..128).step_by(tile) {
                for h in 0..cfg.n_kv_heads {
                    // post-softmax pooling: mean of per-query distributions
                    let mut pooled = vec![0.0f32; t];
                    // pre-softmax pooling: mean query vector, one softmax
                    let mut qbar = vec![0.0f32; d];
                    for r in 0..tile {
                        for qi in 0..g {
                            let hq = h * g + qi;
                            for (pp, &x) in pooled.iter_mut().zip(per_q[t0 + r][hq].iter()) {
                                *pp += x;
                            }
                            let qrow = &qs[(last + t0 + r) * nqd + hq * d..(last + t0 + r) * nqd + (hq + 1) * d];
                            for (qb, &x) in qbar.iter_mut().zip(qrow.iter()) {
                                *qb += x;
                            }
                        }
                    }
                    let inv = 1.0 / (tile * g) as f32;
                    pooled.iter_mut().for_each(|x| *x *= inv);
                    qbar.iter_mut().for_each(|x| *x *= inv);
                    let mut pre = vec![0.0f32; t];
                    for pos in 0..t {
                        pre[pos] = crate::tensor::dot(&qbar, cache.key(h, pos)) / (d as f32).sqrt();
                    }
                    crate::tensor::softmax(&mut pre);
                    let post_idx = topk_indices(&pooled, k);
                    let pre_idx = topk_indices(&pre, k);
                    // recovery vs each member query's own oracle top-k
                    for r in 0..tile {
                        for qi in 0..g {
                            let dist = &per_q[t0 + r][h * g + qi];
                            let own: f32 = topk_indices(dist, k).iter().map(|&i| dist[i as usize]).sum();
                            if own <= 0.0 {
                                continue;
                            }
                            let rec = |idx: &[u32]| -> f64 {
                                (idx.iter().map(|&i| dist[i as usize]).sum::<f32>() / own).min(1.0)
                                    as f64
                            };
                            post_sum += rec(&post_idx);
                            pre_sum += rec(&pre_idx);
                            cnt += 1;
                        }
                    }
                }
            }
            post_r[ti] += post_sum / cnt as f64 / n_prompts as f64;
            pre_r[ti] += pre_sum / cnt as f64 / n_prompts as f64;
        }
    }
    println!("Fig 5 — pooled Top-k recovery (k=10%) vs tile size, match layer {layer}");
    println!("(paper: post-softmax stays flat, pre-softmax degrades with tile size)");
    println!("| tile | post-softmax | pre-softmax |");
    println!("|---|---|---|");
    let mut rows = Vec::new();
    for (ti, &tile) in tiles.iter().enumerate() {
        println!("| {tile} | {:.3} | {:.3} |", post_r[ti], pre_r[ti]);
        rows.push(format!("{tile},{:.4},{:.4}", post_r[ti], pre_r[ti]));
    }
    ctx.write_csv("fig5_pooling", "tile,post_softmax,pre_softmax", &rows)
}

/// Fig. 6: head remapping vs identity vs all-heads-pooled across k%.
///
/// Reports task accuracy *and* margin retention — the fraction of the
/// dense answer-logit margin each variant preserves at the query token.
/// (SynthLM's redundant retrieval blocks saturate raw accuracy at these
/// budgets, exactly like LongBench in the paper's Table 1; the margin
/// exposes the fidelity ordering the paper's Fig. 6 shows.)
pub fn fig6_head_remap(ctx: &EvalCtx) -> anyhow::Result<()> {
    use crate::sparse::{DensePolicy as DP, SparsePolicy};
    let v = &ctx.variants[0];
    let fracs = [0.025f32, 0.05, 0.10, 0.20];
    let tasks = category_tasks(&v.spec, Category::Sqa, ctx.n_prompts(), ctx.ctx_len(), 0xF6);

    // margin of the correct value token over the best other value token
    let lay = v.spec.vocab_layout();
    let margin = |logits: &[f32], ans: u32| -> f64 {
        let best_other = (0..lay.n_entities)
            .map(|j| lay.value_tok(j))
            .filter(|&t| t != ans)
            .map(|t| logits[t as usize])
            .fold(f32::MIN, f32::max);
        (logits[ans as usize] - best_other) as f64
    };
    let prefill_logits = |t: &crate::workload::Task, mut p: Box<dyn SparsePolicy>| -> Vec<f32> {
        let mut st = v.model.new_state(t.prompt.len() + 8);
        v.model.prefill(&t.prompt, &mut st, p.as_mut(), None).0
    };

    println!("Fig 6 — Kascade variants vs Top-k %, model={}, SQA", v.name);
    println!("| k/N | remap acc | no-remap acc | all-pooled acc | remap margin | no-remap margin | all-pooled margin |");
    println!("|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for &f in &fracs {
        let rule = TopKRule::new(f, 16);
        let mut accs = [0.0f64; 3];
        let mut margins = [0.0f64; 3];
        for t in &tasks {
            let ans = t.expect[0];
            let dense_m = margin(&prefill_logits(t, Box::new(DP)), ans).max(1e-9);
            let mut ident = v.cal.plan.clone();
            for hm in ident.head_map.iter_mut() {
                *hm = (0..v.spec.cfg.n_kv_heads).collect();
            }
            let variants: [(StrategyKind, &crate::kascade::KascadePlan); 3] = [
                (StrategyKind::Kascade, &v.cal.plan),
                (StrategyKind::Kascade, &ident),
                (StrategyKind::KascadeAllPooled, &v.cal.plan),
            ];
            for (i, (strat, plan)) in variants.iter().enumerate() {
                let o = run_task(&v.model, t, *strat, plan, rule, None, None);
                accs[i] += o.correct as u8 as f64;
                let pol = strat.build(plan, rule, v.model.cfg.n_layers);
                margins[i] += (margin(&prefill_logits(t, pol), ans) / dense_m).clamp(-1.0, 1.5);
            }
        }
        let n = tasks.len() as f64;
        println!(
            "| {:.1}% | {:.1} | {:.1} | {:.1} | {:.2} | {:.2} | {:.2} |",
            f * 100.0,
            100.0 * accs[0] / n,
            100.0 * accs[1] / n,
            100.0 * accs[2] / n,
            margins[0] / n,
            margins[1] / n,
            margins[2] / n
        );
        rows.push(format!(
            "{f},{},{},{},{},{},{}",
            100.0 * accs[0] / n,
            100.0 * accs[1] / n,
            100.0 * accs[2] / n,
            margins[0] / n,
            margins[1] / n,
            margins[2] / n
        ));
    }
    ctx.write_csv(
        "fig6_head_remap",
        "frac,remap_acc,no_remap_acc,all_pooled_acc,remap_margin,no_remap_margin,all_pooled_margin",
        &rows,
    )
}

/// Fig. 7: accuracy + decode length at Top-k 10% vs 20% (AIME-S).
pub fn fig7_topk_20(ctx: &EvalCtx) -> anyhow::Result<()> {
    let v = &ctx.variants[0];
    let mut gen = WorkloadGen::new(&v.spec, 0xF7);
    let hops = if ctx.opts.fast { 4 } else { 6 };
    let tasks: Vec<_> = (0..ctx.n_prompts()).map(|_| gen.aime(ctx.ctx_len(), hops)).collect();
    println!("Fig 7 — AIME-S accuracy & decode length at Top-k 10% vs 20% (model={})", v.name);
    println!("| strategy | k/N | pass@1 % | decode len |");
    println!("|---|---|---|---|");
    let mut rows = Vec::new();
    for (strat, f) in [
        (StrategyKind::Dense, 1.0f32),
        (StrategyKind::Kascade, 0.10),
        (StrategyKind::Kascade, 0.20),
        (StrategyKind::LessIsMore, 0.10),
        (StrategyKind::LessIsMore, 0.20),
    ] {
        let rule = TopKRule::new(f, 32);
        let mut correct = 0.0;
        let mut dl = 0.0;
        for t in &tasks {
            let (st, lg) = dense_prefill(&v.model, t);
            let shared = (!strat.sparse_prefill()).then_some((&st, &lg));
            let o = run_task(
                &v.model,
                t,
                strat,
                &v.cal.plan,
                rule,
                shared.map(|s| s.0),
                shared.map(|s| s.1),
            );
            correct += o.correct as u8 as f64;
            dl += o.decode_len as f64;
        }
        let n = tasks.len() as f64;
        println!(
            "| {} | {:.0}% | {:.1} | {:.1} |",
            strat.name(),
            f * 100.0,
            100.0 * correct / n,
            dl / n
        );
        rows.push(format!("{},{f},{},{}", strat.name(), 100.0 * correct / n, dl / n));
    }
    let _ = VocabLayout::PAD;
    ctx.write_csv("fig7_topk20", "strategy,frac,pass1,decode_len", &rows)
}
