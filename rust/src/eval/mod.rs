//! Experiment drivers: one regenerator per figure and table in the paper's
//! evaluation section (DESIGN.md §5 maps each to its modules).  Every
//! driver prints a markdown table and writes a CSV under `results/`.

pub mod common;
pub mod figures;
pub mod tables;

pub use common::{EvalCtx, EvalOptions, StrategyKind};

/// Run one named experiment (or "all").
pub fn run(name: &str, opts: &EvalOptions) -> anyhow::Result<()> {
    let names: Vec<&str> = if name == "all" {
        vec![
            "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "table1", "table2", "table3",
        ]
    } else {
        vec![name]
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    let ctx = EvalCtx::new(opts);
    for n in names {
        println!("\n================ {n} ================");
        match n {
            "fig1" => figures::fig1_topk_mass(&ctx)?,
            "fig2" => figures::fig2_oracle_sweep(&ctx)?,
            "fig3" => figures::fig3_similarity(&ctx)?,
            "fig4" => figures::fig4_importance(&ctx)?,
            "fig5" => figures::fig5_pooling(&ctx)?,
            "fig6" => figures::fig6_head_remap(&ctx)?,
            "fig7" => figures::fig7_topk_20(&ctx)?,
            "table1" => tables::table1_longbench(&ctx)?,
            "table2" => tables::table2_aime(&ctx)?,
            "table3" => tables::table3_kernels(&ctx)?,
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
    }
    Ok(())
}
