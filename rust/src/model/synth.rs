//! **SynthLM**: a synthetic GQA transformer with *wired* circuits, built so
//! every phenomenon Kascade exploits is genuinely present (DESIGN.md §2):
//!
//! * **Retrieval circuit** — "fact" (pair) tokens `P(i, j)` embed entity
//!   `i`'s code in the KEY subspace and value `j`'s code in the PAYLOAD
//!   subspace.  Match heads attend from a query token carrying `code(i)`
//!   (the key token `K_i`, or a value token `V_i` during chain decoding) to
//!   every `P(i, *)` in context and copy the payload into the OUT subspace,
//!   which the unembedding reads.  Task accuracy therefore *requires*
//!   long-range retrieval: drop the needle from the attended set and the
//!   answer is wrong (StreamingLLM-style windows score ~0, as in Table 2).
//! * **Intrinsic sparsity** — match/topic scores are peaked (softmax gain
//!   `beta`), diffuse heads are near-uniform; layer 0 carries no match
//!   heads, so its distributions are flat (the paper's layer-0 exception).
//! * **Cross-layer similarity blocks** — head weights are generated per
//!   *block* of consecutive layers and perturbed with noise growing inside
//!   the block; diffuse-head directions are block-specific, so similarity
//!   is high within a block and drops across block boundaries — planted
//!   ground truth the anchor-selection DP should recover.
//! * **Head permutation** — the KV-slot order of (match, topic, diffuse,
//!   diffuse) is permuted per layer, so identity head mapping across layers
//!   fails and head remapping (Sec. 3.5) is required.
//! * **Depth-decaying importance** — output gains decay per block, so
//!   `w_l = 1 - cos(x, y)` falls with depth (Fig. 4) while early-block
//!   retrieval still dominates the logits.

use super::weights::Weights;
use crate::config::ModelConfig;
use crate::model::Model;
use crate::tensor::Rng;

/// Token-id layout over the vocabulary.
#[derive(Debug, Clone, Copy)]
pub struct VocabLayout {
    pub n_entities: usize,
    pub vocab: usize,
}

impl VocabLayout {
    pub const PAD: u32 = 0;
    pub const BOS: u32 = 1;
    pub const QUERY: u32 = 2;

    pub fn new(n_entities: usize, vocab: usize) -> Self {
        let l = Self { n_entities, vocab };
        assert!(l.pair_base() + n_entities * n_entities <= l.filler_base());
        l
    }

    /// Key token of entity `i` (appears at the query site).
    pub fn key_tok(&self, i: usize) -> u32 {
        (16 + i) as u32
    }

    /// Value token of entity `j` (the answer; also re-triggers entity `j`
    /// when fed back during chain decoding).
    pub fn value_tok(&self, j: usize) -> u32 {
        (16 + self.n_entities + j) as u32
    }

    fn pair_base(&self) -> usize {
        16 + 2 * self.n_entities
    }

    /// Fact token binding key entity `i` to value entity `j`.
    pub fn pair_tok(&self, i: usize, j: usize) -> u32 {
        (self.pair_base() + i * self.n_entities + j) as u32
    }

    fn filler_base(&self) -> usize {
        self.pair_base() + self.n_entities * self.n_entities
    }

    pub fn n_filler(&self) -> usize {
        self.vocab - self.filler_base()
    }

    /// `n`-th filler token.
    pub fn filler_tok(&self, n: usize) -> u32 {
        (self.filler_base() + n % self.n_filler()) as u32
    }

    /// Entity of a value token, if it is one.
    pub fn value_entity(&self, tok: u32) -> Option<usize> {
        let t = tok as usize;
        let lo = 16 + self.n_entities;
        (lo..lo + self.n_entities).contains(&t).then(|| t - lo)
    }

    /// Reserved terminal entity for chain tasks.
    pub fn term_entity(&self) -> usize {
        self.n_entities - 1
    }
}

/// KV-head roles in a layer.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Role {
    Match,
    Topic,
    Diffuse(usize), // distinct diffuse identities
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub cfg: ModelConfig,
    pub seed: u64,
    /// First layer of each match block (layer 0 is always block-less).
    pub block_starts: Vec<usize>,
    /// Softmax gain of match scores (needle separation).
    pub match_gain: f32,
    /// Softmax gain of topic scores.
    pub topic_gain: f32,
    /// Weight-noise growth per layer inside a block (similarity decay).
    pub block_noise: f32,
    /// Output-gain decay per block (importance decay, Fig. 4).
    pub out_decay: f32,
    /// Diffuse-head write gain into OUT (organic noise floor).
    pub diffuse_out: f32,
    pub n_entities: usize,
    pub n_topics: usize,
}

impl SynthSpec {
    /// Long-context evaluation preset (NoPE).
    pub fn eval_base(seed: u64) -> Self {
        Self {
            cfg: ModelConfig::eval_base(),
            seed,
            block_starts: vec![1, 4, 8, 12],
            match_gain: 22.0,
            topic_gain: 5.0,
            block_noise: 0.01,
            out_decay: 0.78,
            diffuse_out: 0.05,
            n_entities: 56,
            n_topics: 16,
        }
    }

    /// PJRT-artifact-compatible preset (RoPE; contexts <= ~1k so codes on
    /// the low-frequency rotary dims stay coherent).
    pub fn pjrt_small(seed: u64) -> Self {
        Self {
            cfg: ModelConfig::pjrt_small(),
            ..Self::eval_base(seed)
        }
    }

    pub fn vocab_layout(&self) -> VocabLayout {
        VocabLayout::new(self.n_entities, self.cfg.vocab)
    }

    /// Block index of a layer (layer 0 -> none).
    fn block_of(&self, layer: usize) -> Option<usize> {
        if layer == 0 {
            return None;
        }
        self.block_starts.iter().rposition(|&s| s <= layer)
    }

    pub fn build(&self) -> Model {
        let cfg = self.cfg;
        cfg.validate().expect("invalid synth config");
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        let n_kv = cfg.n_kv_heads;
        let g = cfg.group();
        assert!(n_kv >= 2, "need at least match + one other kv head");
        let mut w = Weights::zeros(&cfg);
        let mut rng = Rng::new(self.seed);
        let lay = self.vocab_layout();

        // --- subspace slices (head-dim sized) ------------------------------
        let qk = 0; // query-side entity code
        let key = dh; // fact-key code
        let pay = 2 * dh; // payload (value identity)
        let out = 3 * dh; // written by match heads, read by unembed
        let topic = 4 * dh; // topic codes on filler tokens
        let local = 5 * dh; // per-token random identity

        // Code support inside a 32-dim slice: with RoPE only the low-
        // frequency rotary dims stay phase-coherent over long offsets.
        let support: Vec<usize> = if cfg.rope {
            let half = dh / 2;
            (half / 2..half).flat_map(|i| [i, half + i]).collect()
        } else {
            (0..dh).collect()
        };

        // --- codes ---------------------------------------------------------
        let mut code_rng = Rng::new(self.seed ^ 0xC0DE);
        let mk_code = |r: &mut Rng| {
            let mut c = vec![0.0f32; dh];
            let u = r.unit_vector(support.len());
            for (s, &v) in support.iter().zip(u.iter()) {
                c[*s] = v;
            }
            c
        };
        let ent_codes: Vec<Vec<f32>> = (0..self.n_entities).map(|_| mk_code(&mut code_rng)).collect();
        let val_codes: Vec<Vec<f32>> = (0..self.n_entities).map(|_| mk_code(&mut code_rng)).collect();
        let topic_codes: Vec<Vec<f32>> = (0..self.n_topics).map(|_| mk_code(&mut code_rng)).collect();

        // --- embeddings ------------------------------------------------
        // Each token's embedding is normalized to ||x|| = sqrt(D) so
        // RMSNorm at layer input is ~identity.
        let scale_to = (dm as f32).sqrt();
        let mut set_emb = |tok: u32, parts: Vec<(usize, &[f32], f32)>, rng: &mut Rng| {
            let row = tok as usize * dm;
            let mut e = vec![0.0f32; dm];
            for (off, code, frac) in parts {
                let a = (frac * dm as f32).sqrt();
                for (i, &c) in code.iter().enumerate() {
                    e[off + i] += a * c;
                }
            }
            // local identity + tiny noise everywhere
            let id = rng.unit_vector(dh);
            let a = (0.15 * dm as f32).sqrt();
            for (i, &c) in id.iter().enumerate() {
                e[local + i] += a * c;
            }
            let n = crate::tensor::norm(&e).max(1e-6);
            for (dst, x) in w.w_e[row..row + dm].iter_mut().zip(e.iter()) {
                *dst = x / n * scale_to;
            }
        };

        for t in 0..16u32 {
            set_emb(t, vec![], &mut rng); // specials: local-only
        }
        for i in 0..self.n_entities {
            set_emb(lay.key_tok(i), vec![(qk, &ent_codes[i], 0.75)], &mut rng);
            // value token: answer identity + chain re-trigger
            set_emb(lay.value_tok(i), vec![(qk, &ent_codes[i], 0.75)], &mut rng);
        }
        for i in 0..self.n_entities {
            for j in 0..self.n_entities {
                set_emb(
                    lay.pair_tok(i, j),
                    vec![(key, &ent_codes[i], 0.45), (pay, &val_codes[j], 0.45)],
                    &mut rng,
                );
            }
        }
        for f in 0..lay.n_filler() {
            let t = f % self.n_topics;
            set_emb(lay.filler_tok(f), vec![(topic, &topic_codes[t], 0.5)], &mut rng);
        }

        // --- per-block base head weights -----------------------------------
        // gains: score = (q . k) / sqrt(dh); embeddings put amplitude
        // a = sqrt(frac * D) on each code, so a matched pair contributes
        // a_q * a_k * cq * ck / sqrt(dh).  Solve cq * ck for the target gain.
        let amp_qk = (0.75f32 * dm as f32).sqrt();
        let amp_key = (0.45f32 * dm as f32).sqrt();
        let amp_topic = (0.5f32 * dm as f32).sqrt();
        let c_match = (self.match_gain * (dh as f32).sqrt() / (amp_qk * amp_key)).sqrt();
        let c_topic = (self.topic_gain * (dh as f32).sqrt() / (amp_topic * amp_topic)).sqrt();

        struct HeadBase {
            wq_m: Vec<f32>, // [dm, dh] match-query projection
            wq_d: Vec<f32>, // diffuse-query projection
            wk: Vec<f32>,   // [dm, dh]
            wv: Vec<f32>,   // [dm, dh]
        }
        let n_blocks = self.block_starts.len();
        let mut bases: Vec<Vec<HeadBase>> = Vec::new(); // [block][role-slot]
        let roles: Vec<Role> = {
            let mut r = vec![Role::Match, Role::Topic];
            for dnum in 0..n_kv.saturating_sub(2) {
                r.push(Role::Diffuse(dnum));
            }
            r
        };
        let ident = |off: usize, c: f32| {
            let mut m = vec![0.0f32; dm * dh];
            for j in 0..dh {
                m[(off + j) * dh + j] = c;
            }
            m
        };
        let randm = |rng: &mut Rng, scale: f32| {
            let mut m = vec![0.0f32; dm * dh];
            rng.fill_normal(&mut m, scale);
            m
        };
        for _b in 0..n_blocks {
            let mut heads = Vec::new();
            for role in &roles {
                let hb = match role {
                    Role::Match => HeadBase {
                        wq_m: ident(qk, c_match),
                        wq_d: randm(&mut rng, 0.02),
                        wk: ident(key, c_match),
                        wv: ident(pay, 1.0),
                    },
                    Role::Topic => HeadBase {
                        wq_m: ident(topic, c_topic),
                        wq_d: randm(&mut rng, 0.02),
                        wk: ident(topic, c_topic),
                        wv: ident(local, 0.5),
                    },
                    Role::Diffuse(_) => HeadBase {
                        wq_m: randm(&mut rng, 0.03),
                        wq_d: randm(&mut rng, 0.03),
                        wk: randm(&mut rng, 0.03),
                        wv: ident(local, 0.5),
                    },
                };
                heads.push(hb);
            }
            bases.push(heads);
        }

        // --- assemble layers -------------------------------------------
        for l in 0..cfg.n_layers {
            let lw = &mut w.layers[l];
            let block = self.block_of(l);
            // per-layer slot permutation (layer 0: no match head)
            let mut slots: Vec<Role> = roles.clone();
            if l == 0 {
                slots[0] = Role::Diffuse(7); // replace match with diffuse
            }
            let mut perm: Vec<usize> = (0..n_kv).collect();
            let mut prng = Rng::new(self.seed ^ (0x9ead * (l as u64 + 1)));
            prng.shuffle(&mut perm);
            let (bi, depth) = match block {
                Some(b) => (b, l - self.block_starts[b]),
                None => (0, 0),
            };
            let noise = self.block_noise * depth as f32;
            let alpha = self.out_decay.powi(bi as i32) * if l == 0 { 0.4 } else { 1.0 };

            let mut nrng = Rng::new(self.seed ^ (0x0150 * (l as u64 + 3)));
            for (slot_pos, &kv_slot) in perm.iter().enumerate() {
                let role = slots[slot_pos];
                // layer 0 swaps its match slot for a fresh diffuse head; the
                // weights must follow the role, not just the output gains
                let fresh_diffuse;
                let base = if role == roles[slot_pos] {
                    &bases[bi][slot_pos.min(bases[bi].len() - 1)]
                } else {
                    let mut drng = Rng::new(self.seed ^ 0xd1ff ^ (l as u64) << 8);
                    fresh_diffuse = HeadBase {
                        wq_m: randm(&mut drng, 0.03),
                        wq_d: randm(&mut drng, 0.03),
                        wk: randm(&mut drng, 0.03),
                        wv: ident(local, 0.5),
                    };
                    &fresh_diffuse
                };
                // copy base + in-block noise into this layer's kv slot
                let put = |dst: &mut [f32], src: &[f32], ncols_total: usize, col0: usize, nrng: &mut Rng, noise: f32| {
                    for r in 0..dm {
                        for j in 0..dh {
                            let v = src[r * dh + j] + if noise > 0.0 { nrng.normal() * noise } else { 0.0 };
                            dst[r * ncols_total + col0 + j] = v;
                        }
                    }
                };
                let kv_cols = n_kv * dh;
                put(&mut lw.wk, &base.wk, kv_cols, kv_slot * dh, &mut nrng, noise);
                put(&mut lw.wv, &base.wv, kv_cols, kv_slot * dh, &mut nrng, 0.0);
                // query heads of this group: slot 0 = role query, others diffuse
                let q_cols = cfg.n_q_heads * dh;
                for qi in 0..g {
                    let src = if qi == 0 { &base.wq_m } else { &base.wq_d };
                    put(&mut lw.wq, src, q_cols, (kv_slot * g + qi) * dh, &mut nrng, noise);
                }
                // output wiring
                let o_gain = match role {
                    Role::Match => alpha * 1.2,
                    Role::Topic => 0.02,
                    Role::Diffuse(_) => self.diffuse_out * alpha,
                };
                let o_dst = match role {
                    Role::Match | Role::Diffuse(_) => out,
                    Role::Topic => local,
                };
                for qi in 0..g {
                    let hq = kv_slot * g + qi;
                    let gain = if qi == 0 { o_gain } else { o_gain * 0.1 };
                    for j in 0..dh {
                        lw.wo[(hq * dh + j) * dm + o_dst + j] = gain;
                    }
                }
            }
            // tiny MLP noise for realism
            let mut mrng = Rng::new(self.seed ^ (0x31ab7 * (l as u64 + 5)));
            mrng.fill_normal(&mut lw.w1, 0.01);
            mrng.fill_normal(&mut lw.w3, 0.01);
            mrng.fill_normal(&mut lw.w2, 0.01);
        }

        // --- unembedding -------------------------------------------------
        // value tokens read OUT; everything else gets a tiny random column
        // so argmax is well-defined.
        let mut urng = Rng::new(self.seed ^ 0x0ead);
        for t in 0..cfg.vocab {
            for r in 0..dm {
                w.w_u[r * cfg.vocab + t] = urng.normal() * 0.01;
            }
        }
        for j in 0..self.n_entities {
            let t = lay.value_tok(j) as usize;
            for (i, &c) in val_codes[j].iter().enumerate() {
                w.w_u[(out + i) * cfg.vocab + t] = c * 2.0;
            }
        }

        Model::new(cfg, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::DensePolicy;
    use crate::tensor::argmax;

    fn small_spec() -> SynthSpec {
        let mut s = SynthSpec::eval_base(42);
        s.cfg.n_layers = 6;
        s.block_starts = vec![1, 3];
        s
    }

    /// The wired retrieval circuit must work end-to-end under dense
    /// attention: "... P(i,j) ... QUERY K_i" -> argmax logit = V_j.
    #[test]
    fn dense_retrieval_is_exact() {
        let spec = small_spec();
        let m = spec.build();
        let lay = spec.vocab_layout();
        let mut rng = Rng::new(7);
        for trial in 0..5 {
            let i = rng.below(lay.n_entities - 1);
            let j = rng.below(lay.n_entities - 1);
            let mut toks = vec![VocabLayout::BOS];
            for f in 0..96 {
                toks.push(lay.filler_tok(f * 7 + trial));
            }
            toks.insert(20 + trial * 9, lay.pair_tok(i, j));
            toks.push(VocabLayout::QUERY);
            toks.push(lay.key_tok(i));
            let mut st = m.new_state(toks.len() + 8);
            let (logits, _) = m.prefill(&toks, &mut st, &mut DensePolicy, None);
            assert_eq!(
                argmax(&logits) as u32,
                lay.value_tok(j),
                "trial {trial}: retrieval failed"
            );
        }
    }

    /// Majority aggregation (Summ-style): repeated pair wins over singleton.
    #[test]
    fn dense_majority_aggregation() {
        let spec = small_spec();
        let m = spec.build();
        let lay = spec.vocab_layout();
        let (i, j_major, j_minor) = (3, 9, 21);
        let mut toks = vec![VocabLayout::BOS];
        for f in 0..128 {
            toks.push(lay.filler_tok(f));
        }
        for slot in [10, 40, 70] {
            toks[slot] = lay.pair_tok(i, j_major);
        }
        toks[100] = lay.pair_tok(i, j_minor);
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(i));
        let mut st = m.new_state(toks.len() + 8);
        let (logits, _) = m.prefill(&toks, &mut st, &mut DensePolicy, None);
        assert_eq!(argmax(&logits) as u32, lay.value_tok(j_major));
        assert!(logits[lay.value_tok(j_major) as usize] > logits[lay.value_tok(j_minor) as usize]);
    }

    /// Chain following: V_j re-triggers entity j, so greedy decode walks
    /// the planted chain to the terminal.
    #[test]
    fn dense_chain_following() {
        let spec = small_spec();
        let m = spec.build();
        let lay = spec.vocab_layout();
        // chain 5 -> 11 -> 30 -> TERM
        let term = lay.term_entity();
        let hops = [(5usize, 11usize), (11, 30), (30, term)];
        let mut toks = vec![VocabLayout::BOS];
        for f in 0..128 {
            toks.push(lay.filler_tok(f * 3 + 1));
        }
        for (n, (a, b)) in hops.iter().enumerate() {
            toks[15 + 37 * n] = lay.pair_tok(*a, *b);
        }
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(5));
        let mut st = m.new_state(toks.len() + 16);
        let (logits, _) = m.prefill(&toks, &mut st, &mut DensePolicy, None);
        let out = m.greedy_decode(&logits, &mut st, &mut DensePolicy, 8, |t| {
            lay.value_entity(t) == Some(term)
        });
        let want: Vec<u32> = vec![
            lay.value_tok(11),
            lay.value_tok(30),
            lay.value_tok(term),
        ];
        assert_eq!(out, want);
    }

    /// Layer 0 must have visibly flatter attention than match layers
    /// (Fig. 1's layer-0 exception).
    #[test]
    fn layer0_attention_is_flat() {
        let spec = small_spec();
        let m = spec.build();
        let lay = spec.vocab_layout();
        let mut toks = vec![VocabLayout::BOS];
        for f in 0..255 {
            toks.push(lay.filler_tok(f));
        }
        toks[50] = lay.pair_tok(2, 3);
        toks.push(VocabLayout::QUERY);
        toks.push(lay.key_tok(2));
        let mut st = m.new_state(toks.len() + 4);
        let req = crate::model::CaptureRequest { probe_positions: vec![toks.len() - 1] };
        let (_, cap) = m.prefill(&toks, &mut st, &mut DensePolicy, Some(&req));
        let cap = cap.unwrap();
        let mass_top16 = |d: &Vec<f32>| -> f32 {
            let idx = crate::tensor::topk_indices(d, 16);
            idx.iter().map(|&i| d[i as usize]).sum()
        };
        // layer 0: max over heads of top-16 mass should be modest;
        // match block layers should have a near-1.0 head.
        let l0: f32 = cap.probes[0].dists[0].iter().map(mass_top16).fold(0.0, f32::max);
        let l1: f32 = cap.probes[0].dists[1].iter().map(mass_top16).fold(0.0, f32::max);
        // GQA pooling mixes the peaked match-query with its flat diffuse
        // sibling, so the pooled ceiling is ~(1 + eps)/2.
        assert!(l1 > 0.45, "match layer top-16 mass {l1}");
        assert!(l0 < 0.25, "layer0 top-16 mass {l0} not flat");
        assert!(l0 < l1, "layer0 {l0} should be flatter than match layer {l1}");
    }

    /// Head-slot permutation: the match head sits at different KV slots in
    /// different layers (so identity head mapping must fail).
    #[test]
    fn head_slots_are_permuted_across_layers() {
        let spec = small_spec();
        let m = spec.build();
        // find the match slot per layer by looking for the KEY-identity
        // structure in wk
        let dh = spec.cfg.d_head;
        let key_off = dh;
        let mut slots = Vec::new();
        for l in 1..spec.cfg.n_layers {
            let lw = &m.w.layers[l];
            let mut best = (0, 0.0f32);
            for s in 0..spec.cfg.n_kv_heads {
                let mut diag = 0.0;
                for j in 0..dh {
                    diag += lw.wk[(key_off + j) * spec.cfg.n_kv_heads * dh + s * dh + j].abs();
                }
                if diag > best.1 {
                    best = (s, diag);
                }
            }
            slots.push(best.0);
        }
        let first = slots[0];
        assert!(
            slots.iter().any(|&s| s != first),
            "match slot identical across all layers: {slots:?}"
        );
    }

    #[test]
    fn vocab_layout_partitions() {
        let lay = VocabLayout::new(56, 4096);
        assert_eq!(lay.key_tok(0), 16);
        assert_eq!(lay.value_tok(0), 72);
        assert_eq!(lay.pair_tok(0, 0), 128);
        assert!(lay.pair_tok(55, 55) < lay.filler_tok(0));
        assert!(lay.n_filler() > 500);
        assert_eq!(lay.value_entity(lay.value_tok(7)), Some(7));
        assert_eq!(lay.value_entity(lay.key_tok(7)), None);
        assert_eq!(lay.value_entity(9999), None);
    }

    #[test]
    fn generator_is_deterministic() {
        let a = small_spec().build();
        let b = small_spec().build();
        assert_eq!(a.w.w_e, b.w.w_e);
        assert_eq!(a.w.layers[3].wq, b.w.layers[3].wq);
    }
}
