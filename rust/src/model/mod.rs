//! Native GQA transformer: weights, forward pass (prefill + decode) driven
//! by a [`crate::sparse::SparsePolicy`], calibration capture hooks, and the
//! **SynthLM** generator — a synthetic model whose weights are *wired* so
//! that task accuracy genuinely depends on long-range attention fidelity
//! (DESIGN.md §2: the substitution for Llama-3.1-8B etc.).

pub mod forward;
pub mod synth;
pub mod weights;

pub use forward::{BatchScratch, CaptureRequest, DecodeReq, Model, SeqState, PREFILL_TILE};
pub use synth::{SynthSpec, VocabLayout};
pub use weights::{LayerWeights, Weights};
