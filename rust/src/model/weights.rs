//! Weight containers + binary export for the PJRT path.
//!
//! Layout matches python/compile/model.py: all projections are row-major
//! `[in, out]` so `x @ W` on the JAX side equals `matvec_t(x, W)` here.
//! `export_bin` writes a little-endian f32 blob + JSON manifest the Rust
//! runtime feeds to the HLO artifacts (weights are runtime arguments, never
//! baked into HLO).

use crate::config::ModelConfig;
use std::io::Write;
use std::path::Path;

#[derive(Clone)]
pub struct LayerWeights {
    pub ln1: Vec<f32>, // [D]
    pub wq: Vec<f32>,  // [D, n_q*d]
    pub wk: Vec<f32>,  // [D, n_kv*d]
    pub wv: Vec<f32>,  // [D, n_kv*d]
    pub wo: Vec<f32>,  // [n_q*d, D]
    pub ln2: Vec<f32>, // [D]
    pub w1: Vec<f32>,  // [D, F]
    pub w3: Vec<f32>,  // [D, F]
    pub w2: Vec<f32>,  // [F, D]
}

#[derive(Clone)]
pub struct Weights {
    pub layers: Vec<LayerWeights>,
    pub w_e: Vec<f32>, // [V, D]
    pub lnf: Vec<f32>, // [D]
    pub w_u: Vec<f32>, // [D, V]
}

impl Weights {
    pub fn zeros(cfg: &ModelConfig) -> Self {
        let (dm, dh, f, v) = (cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1: vec![1.0; dm],
                wq: vec![0.0; dm * cfg.n_q_heads * dh],
                wk: vec![0.0; dm * cfg.n_kv_heads * dh],
                wv: vec![0.0; dm * cfg.n_kv_heads * dh],
                wo: vec![0.0; cfg.n_q_heads * dh * dm],
                ln2: vec![1.0; dm],
                w1: vec![0.0; dm * f],
                w3: vec![0.0; dm * f],
                w2: vec![0.0; f * dm],
            })
            .collect();
        Self {
            layers,
            w_e: vec![0.0; v * dm],
            lnf: vec![1.0; dm],
            w_u: vec![0.0; dm * v],
        }
    }

    pub fn embedding(&self, tok: usize, d_model: usize) -> &[f32] {
        &self.w_e[tok * d_model..(tok + 1) * d_model]
    }

    /// Ordered flat views: (name, shape, data) — the export/import schema
    /// shared with the PJRT runtime.
    pub fn tensors(&self, cfg: &ModelConfig) -> Vec<(String, Vec<usize>, &[f32])> {
        let (dm, dh, f, v) = (cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab);
        let mut out: Vec<(String, Vec<usize>, &[f32])> = vec![(
            "w_e".into(),
            vec![v, dm],
            &self.w_e[..],
        )];
        for (i, lw) in self.layers.iter().enumerate() {
            let p = |n: &str| format!("layer{i}.{n}");
            out.push((p("ln1"), vec![dm], &lw.ln1));
            out.push((p("wq"), vec![dm, cfg.n_q_heads * dh], &lw.wq));
            out.push((p("wk"), vec![dm, cfg.n_kv_heads * dh], &lw.wk));
            out.push((p("wv"), vec![dm, cfg.n_kv_heads * dh], &lw.wv));
            out.push((p("wo"), vec![cfg.n_q_heads * dh, dm], &lw.wo));
            out.push((p("ln2"), vec![dm], &lw.ln2));
            out.push((p("w1"), vec![dm, f], &lw.w1));
            out.push((p("w3"), vec![dm, f], &lw.w3));
            out.push((p("w2"), vec![f, dm], &lw.w2));
        }
        out.push(("lnf".into(), vec![dm], &self.lnf));
        out.push(("w_u".into(), vec![dm, v], &self.w_u));
        out
    }

    /// Write `<path>.bin` (LE f32) and `<path>.json` (tensor index).
    pub fn export_bin(&self, cfg: &ModelConfig, path: &Path) -> anyhow::Result<()> {
        use crate::jsonutil::Json;
        let mut bin = std::io::BufWriter::new(std::fs::File::create(path.with_extension("bin"))?);
        let mut index = Vec::new();
        let mut offset = 0usize;
        for (name, shape, data) in self.tensors(cfg) {
            for &x in data {
                bin.write_all(&x.to_le_bytes())?;
            }
            index.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("shape", Json::usize_arr(&shape)),
                ("offset", Json::num(offset as f64)),
                ("len", Json::num(data.len() as f64)),
            ]));
            offset += data.len();
        }
        let meta = Json::obj(vec![
            (
                "config",
                Json::obj(vec![
                    ("n_layers", Json::num(cfg.n_layers as f64)),
                    ("d_model", Json::num(cfg.d_model as f64)),
                    ("n_q_heads", Json::num(cfg.n_q_heads as f64)),
                    ("n_kv_heads", Json::num(cfg.n_kv_heads as f64)),
                    ("d_head", Json::num(cfg.d_head as f64)),
                    ("d_ff", Json::num(cfg.d_ff as f64)),
                    ("vocab", Json::num(cfg.vocab as f64)),
                    ("rope_theta", Json::num(cfg.rope_theta as f64)),
                ]),
            ),
            ("tensors", Json::Arr(index)),
        ]);
        std::fs::write(path.with_extension("json"), meta.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_enumeration_covers_all_weights() {
        let cfg = ModelConfig::pjrt_small();
        let w = Weights::zeros(&cfg);
        let ts = w.tensors(&cfg);
        assert_eq!(ts.len(), 3 + 9 * cfg.n_layers);
        let total: usize = ts.iter().map(|(_, _, d)| d.len()).sum();
        let expect_layer = cfg.d_model * 2
            + cfg.d_model * cfg.n_q_heads * cfg.d_head * 2
            + cfg.d_model * cfg.n_kv_heads * cfg.d_head * 2
            + cfg.d_model * cfg.d_ff * 3;
        assert_eq!(
            total,
            cfg.vocab * cfg.d_model * 2 + cfg.d_model + cfg.n_layers * expect_layer
        );
    }

    #[test]
    fn export_bin_roundtrip_header() {
        let cfg = ModelConfig { n_layers: 1, ..ModelConfig::pjrt_small() };
        let w = Weights::zeros(&cfg);
        let dir = std::env::temp_dir().join("kascade_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights");
        w.export_bin(&cfg, &path).unwrap();
        let meta = crate::jsonutil::Json::parse(
            &std::fs::read_to_string(path.with_extension("json")).unwrap(),
        )
        .unwrap();
        assert_eq!(
            meta.get("config").unwrap().get("n_layers").unwrap().as_usize(),
            Some(1)
        );
        let bin_len = std::fs::metadata(path.with_extension("bin")).unwrap().len();
        let total: usize = meta
            .get("tensors")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.get("len").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(bin_len, 4 * total as u64);
    }
}
