//! Native forward pass: policy-driven prefill (tile-based, layer by layer)
//! and decode steps, with optional calibration capture (pooled
//! distributions + importance samples) for the Kascade offline pipeline.

use super::weights::Weights;
use crate::attention::{self, AttnScratch, CostTracker, IndexSet, KvCache, ScorePlanes};
use crate::config::ModelConfig;
use crate::kascade::similarity::{CalibrationCapture, ProbeCapture};
use crate::pool::{ScopedJob, WorkerPool};
use crate::sparse::{Selection, SparsePolicy};
use crate::tensor::{self, matmul_t, matvec_t, rmsnorm, rope};
use crate::tilestore::{SharedTileStore, TierParams, TileStoreError};

/// Prefill Q-tile (matches the paper's 128-query kernel tile).
pub const PREFILL_TILE: usize = 128;

pub struct Model {
    pub cfg: ModelConfig,
    pub w: Weights,
}

/// Per-sequence inference state.
#[derive(Clone)]
pub struct SeqState {
    pub caches: Vec<KvCache>,
    pub pos: usize,
    pub cost: CostTracker,
    /// Attention scratch arena: the policy's current selection plus the
    /// kernel score planes.  Buffers keep their capacity across steps, so
    /// the steady-state decode loop allocates nothing through here.
    pub scratch: AttnScratch,
}

/// One sequence's slot in a step-batched decode call
/// ([`Model::decode_batch`]): the token to feed plus exclusive borrows of
/// the sequence's state and sparse policy.
pub struct DecodeReq<'a> {
    pub token: u32,
    pub st: &'a mut SeqState,
    pub policy: &'a mut dyn SparsePolicy,
}

/// Requests calibration capture during a prefill: pooled per-KV-head
/// distributions and importance samples at the given probe positions.
pub struct CaptureRequest {
    /// Absolute token positions to probe (typically late positions).
    pub probe_positions: Vec<usize>,
}

/// Caller-owned staging for [`Model::decode_batch`]: projection/MLP
/// planes, per-sequence selections, per-(sequence, head) cost shards,
/// per-worker score planes, and the output logits plane.  Buffers are
/// resized (never shrunk in capacity) per call, so a steady-state engine
/// reuses one `BatchScratch` with zero allocations per token.
#[derive(Default)]
pub struct BatchScratch {
    xs: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    delta: Vec<f32>,
    a: Vec<f32>,
    bb: Vec<f32>,
    logits: Vec<f32>,
    vocab: usize,
    sels: Vec<Selection>,
    head_costs: Vec<CostTracker>,
    job_planes: Vec<ScorePlanes>,
}

impl BatchScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Batch-row `i`'s next-token logits from the most recent
    /// [`Model::decode_batch`] call.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Size every plane for a batch of `b` rows (exact lengths — the
    /// batched mat-muls assert them) and `threads` worker slots.
    fn ensure(&mut self, cfg: &ModelConfig, b: usize, threads: usize) {
        let dm = cfg.d_model;
        let nqd = cfg.n_q_heads * cfg.d_head;
        let nkd = cfg.n_kv_heads * cfg.d_head;
        self.h.resize(b * dm, 0.0);
        self.q.resize(b * nqd, 0.0);
        self.k.resize(b * nkd, 0.0);
        self.v.resize(b * nkd, 0.0);
        self.attn.resize(b * nqd, 0.0);
        self.delta.resize(b * dm, 0.0);
        self.a.resize(b * cfg.d_ff, 0.0);
        self.bb.resize(b * cfg.d_ff, 0.0);
        self.logits.resize(b * cfg.vocab, 0.0);
        self.sels.clear();
        self.sels.resize(b, Selection::Dense);
        if self.head_costs.len() < b * cfg.n_kv_heads {
            self.head_costs.resize(b * cfg.n_kv_heads, CostTracker::default());
        }
        while self.job_planes.len() < threads {
            self.job_planes.push(ScorePlanes::default());
        }
    }

    /// Warm capacity for the zero-allocation tests: `b` rows, contexts up
    /// to `len`.
    pub fn reserve(&mut self, cfg: &ModelConfig, b: usize, len: usize) {
        self.ensure(cfg, b, 1);
        self.xs.reserve(b * cfg.d_model);
        for p in &mut self.job_planes {
            p.reserve(cfg.n_q_heads, cfg.n_kv_heads, len);
        }
    }
}

/// One `(sequence, KV head)` attention work item of the parallel decode
/// phase: everything it touches is either shared-immutable (cache, query
/// row, selection) or exclusively its own (output rows, cost shard), so
/// work items schedule on any worker in any order without affecting
/// results.
struct HeadItem<'a> {
    cache: &'a KvCache,
    qrow: &'a [f32],
    /// `None` = dense attention over the full cache.
    sel: Option<&'a IndexSet>,
    h: usize,
    out: &'a mut [f32],
    cost: &'a mut CostTracker,
}

/// Unwrap a tier `ensure`: a spill-store fault mid-forward is
/// unrecoverable — the attention kernels need the tile bytes that were
/// supposed to come back from the store.
// (spill-store corruption mid-forward has no recovery path)
fn tier_ok(r: Result<(), TileStoreError>) {
    if let Err(e) = r {
        panic!("tiered KV ensure failed: {e}");
    }
}

/// Policy phase of one batched-decode layer for one sequence: append the
/// freshly projected K/V row to the layer cache, ask the sequence's
/// policy for its selection (written into the sequence's own scratch),
/// then — for tiered caches — promote whatever the selection needs that
/// the tick-boundary prefetch did not stage (counted as prefetch misses).
// analyze: hot-path
#[allow(clippy::too_many_arguments)]
fn policy_phase(
    r: &mut DecodeReq,
    i: usize,
    layer: usize,
    g: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nqd: usize,
    nkd: usize,
) -> Selection {
    let st = &mut *r.st;
    // analyze: allow(hot-path-alloc) — KvCache::push appends into preallocated pages (cap from new_state)
    st.caches[layer].push(&k[i * nkd..(i + 1) * nkd], &v[i * nkd..(i + 1) * nkd]);
    let sel = r.policy.decode(
        layer,
        &q[i * nqd..(i + 1) * nqd],
        &st.caches[layer],
        g,
        &mut st.scratch,
        &mut st.cost,
    );
    if st.caches[layer].is_tiered() {
        // demand promotion (miss path only) reuses the tier staging
        // buffers; the ensure calls allocate nothing when the tiles
        // are already hot, which the steady-state alloc test relies on
        match sel {
            Selection::Dense => tier_ok(st.caches[layer].ensure_all_hot()),
            Selection::Sparse => tier_ok(st.caches[layer].ensure_hot_for(&st.scratch.sel)),
        }
    }
    sel
}

impl Model {
    pub fn new(cfg: ModelConfig, w: Weights) -> Self {
        Self { cfg, w }
    }

    pub fn new_state(&self, cap: usize) -> SeqState {
        self.new_state_with_dtype(cap, crate::config::KvDtype::F32)
    }

    /// Per-sequence state with an explicit KV storage precision
    /// ([`crate::config::KvDtype`]): the quantization tile equals the
    /// cache page size, so paged-KV blocks and int8 tiles stay aligned.
    pub fn new_state_with_dtype(&self, cap: usize, dtype: crate::config::KvDtype) -> SeqState {
        let caches = (0..self.cfg.n_layers)
            .map(|_| KvCache::with_opts(self.cfg.n_kv_heads, self.cfg.d_head, cap, 16, dtype))
            .collect();
        SeqState { caches, pos: 0, cost: CostTracker::default(), scratch: AttnScratch::new() }
    }

    /// Per-sequence state with tiered int8 KV storage (`docs/kv-tiers.md`).
    /// Layers whose `policy` scans every position (anchors, dense
    /// baselines — [`SparsePolicy::scans_all_positions`]) get flat int8
    /// caches exactly as [`Model::new_state_with_dtype`]; the remaining
    /// (reuse) layers run under `tiers`' hot-tile budget, demoting cold
    /// tiles through an int4 warm shadow into `store` and promoting them
    /// back when the anchor layers' Top-k hints (or a policy-phase miss)
    /// need them.
    pub fn new_state_tiered(
        &self,
        cap: usize,
        policy: &dyn SparsePolicy,
        tiers: TierParams,
        store: &SharedTileStore,
    ) -> SeqState {
        let caches = (0..self.cfg.n_layers)
            .map(|layer| {
                if policy.scans_all_positions(layer) {
                    KvCache::with_opts(
                        self.cfg.n_kv_heads,
                        self.cfg.d_head,
                        cap,
                        16,
                        crate::config::KvDtype::Int8,
                    )
                } else {
                    KvCache::with_tiers(
                        self.cfg.n_kv_heads,
                        self.cfg.d_head,
                        cap,
                        16,
                        layer,
                        tiers,
                        store.clone(),
                    )
                }
            })
            .collect();
        SeqState { caches, pos: 0, cost: CostTracker::default(), scratch: AttnScratch::new() }
    }

    /// KV bytes resident across all layers of `st`.
    pub fn kv_bytes(&self, st: &SeqState) -> usize {
        st.caches.iter().map(|c| c.kv_bytes()).sum()
    }

    /// Project one hidden row into (q, k, v) head vectors with RoPE.
    fn qkv_row(
        &self,
        layer: usize,
        x: &[f32],
        pos: usize,
        q: &mut [f32],
        k: &mut [f32],
        v: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let lw = &self.w.layers[layer];
        let mut h = vec![0.0; cfg.d_model];
        rmsnorm(x, &lw.ln1, &mut h);
        matvec_t(&h, &lw.wq, cfg.d_model, cfg.n_q_heads * cfg.d_head, q);
        matvec_t(&h, &lw.wk, cfg.d_model, cfg.n_kv_heads * cfg.d_head, k);
        matvec_t(&h, &lw.wv, cfg.d_model, cfg.n_kv_heads * cfg.d_head, v);
        if cfg.rope {
            for hq in 0..cfg.n_q_heads {
                rope(&mut q[hq * cfg.d_head..(hq + 1) * cfg.d_head], pos, cfg.rope_theta);
            }
            for hk in 0..cfg.n_kv_heads {
                rope(&mut k[hk * cfg.d_head..(hk + 1) * cfg.d_head], pos, cfg.rope_theta);
            }
        }
    }

    /// Residual attention-write + SwiGLU MLP for one row.
    fn post_row(&self, layer: usize, x: &mut [f32], attn: &[f32]) {
        let cfg = &self.cfg;
        let lw = &self.w.layers[layer];
        let mut delta = vec![0.0; cfg.d_model];
        matvec_t(attn, &lw.wo, cfg.n_q_heads * cfg.d_head, cfg.d_model, &mut delta);
        for (xi, di) in x.iter_mut().zip(delta.iter()) {
            *xi += di;
        }
        let mut h = vec![0.0; cfg.d_model];
        rmsnorm(x, &lw.ln2, &mut h);
        let mut a = vec![0.0; cfg.d_ff];
        let mut b = vec![0.0; cfg.d_ff];
        matvec_t(&h, &lw.w1, cfg.d_model, cfg.d_ff, &mut a);
        matvec_t(&h, &lw.w3, cfg.d_model, cfg.d_ff, &mut b);
        for i in 0..cfg.d_ff {
            let s = a[i] / (1.0 + (-a[i]).exp()); // silu
            a[i] = s * b[i];
        }
        matvec_t(&a, &lw.w2, cfg.d_ff, cfg.d_model, &mut delta);
        for (xi, di) in x.iter_mut().zip(delta.iter()) {
            *xi += di;
        }
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let cfg = &self.cfg;
        let mut h = vec![0.0; cfg.d_model];
        rmsnorm(x, &self.w.lnf, &mut h);
        let mut out = vec![0.0; cfg.vocab];
        matvec_t(&h, &self.w.w_u, cfg.d_model, cfg.vocab, &mut out);
        out
    }

    /// Policy-driven prefill over `tokens`, processed layer-by-layer in
    /// Q-tiles of [`PREFILL_TILE`].  Returns logits of the last token.
    ///
    /// With `capture`, also returns pooled-score / importance probes for
    /// the Kascade calibration pipeline (computed from the *dense* score
    /// oracle regardless of the policy — calibration always runs dense).
    pub fn prefill(
        &self,
        tokens: &[u32],
        st: &mut SeqState,
        policy: &mut dyn SparsePolicy,
        capture: Option<&CaptureRequest>,
    ) -> (Vec<f32>, Option<CalibrationCapture>) {
        let cfg = &self.cfg;
        let t_total = tokens.len();
        let SeqState { caches, pos, cost, scratch } = st;
        let base = *pos;
        let nqd = cfg.n_q_heads * cfg.d_head;
        // hidden states for the whole chunk
        let mut xs: Vec<f32> = Vec::with_capacity(t_total * cfg.d_model);
        for &t in tokens {
            xs.extend_from_slice(self.w.embedding(t as usize, cfg.d_model));
        }
        let mut probes: Vec<ProbeCapture> = capture
            .map(|c| {
                c.probe_positions
                    .iter()
                    .map(|_| ProbeCapture {
                        dists: vec![Vec::new(); cfg.n_layers],
                        importance: vec![0.0; cfg.n_layers],
                    })
                    .collect()
            })
            .unwrap_or_default();

        let mut qbuf = vec![0.0f32; t_total * nqd];
        let mut attn = vec![0.0f32; t_total * nqd];
        for layer in 0..cfg.n_layers {
            // project + append kv for every token of the chunk
            let mut k = vec![0.0; cfg.n_kv_heads * cfg.d_head];
            let mut v = vec![0.0; cfg.n_kv_heads * cfg.d_head];
            for t in 0..t_total {
                let x = &xs[t * cfg.d_model..(t + 1) * cfg.d_model];
                let q = &mut qbuf[t * nqd..(t + 1) * nqd];
                self.qkv_row(layer, x, base + t, q, &mut k, &mut v);
                caches[layer].push(&k, &v);
            }
            // attention per Q-tile
            let mut t0 = 0;
            while t0 < t_total {
                let tlen = PREFILL_TILE.min(t_total - t0);
                let qs = &qbuf[t0 * nqd..(t0 + tlen) * nqd];
                let out = &mut attn[t0 * nqd..(t0 + tlen) * nqd];
                // tiles are keyed by ABSOLUTE position so policy state
                // (Kascade anchor Top-k) stays consistent across chunked
                // prefill calls — chunk-relative ids would alias slot 0
                // of every chunk onto the same policy state
                let tile_idx = (base + t0) / PREFILL_TILE;
                let sel = policy.prefill_tile(
                    layer,
                    tile_idx,
                    base + t0,
                    qs,
                    &caches[layer],
                    cfg.group(),
                    scratch,
                    cost,
                );
                if caches[layer].is_tiered() {
                    match sel {
                        Selection::Dense => tier_ok(caches[layer].ensure_all_hot()),
                        Selection::Sparse => tier_ok(caches[layer].ensure_hot_for(&scratch.sel)),
                    }
                }
                let cache = &caches[layer];
                let AttnScratch { sel: selset, planes } = scratch;
                match sel {
                    Selection::Dense => attention::prefill_dense_tile(
                        qs,
                        base + t0,
                        cache,
                        cfg.group(),
                        out,
                        planes,
                        cost,
                    ),
                    Selection::Sparse => attention::prefill_sparse_tile(
                        qs,
                        base + t0,
                        cache,
                        cfg.group(),
                        selset,
                        out,
                        planes,
                        cost,
                    ),
                }
                t0 += tlen;
            }
            // calibration probes (dense oracle, before residual update)
            if let Some(cap) = capture {
                if caches[layer].is_tiered() {
                    // the dense probe oracle scans every position
                    tier_ok(caches[layer].ensure_all_hot());
                }
                let cache = &caches[layer];
                for (pi, &pp) in cap.probe_positions.iter().enumerate() {
                    if pp < base || pp >= base + t_total {
                        continue;
                    }
                    let t = pp - base;
                    let q = &qbuf[t * nqd..(t + 1) * nqd];
                    attention::decode_pooled_scores_upto(
                        q,
                        pp + 1,
                        cache,
                        cfg.group(),
                        &mut scratch.planes,
                        cost,
                    );
                    probes[pi].dists[layer] = (0..scratch.planes.pooled_heads())
                        .map(|h| scratch.planes.pooled_head(h).to_vec())
                        .collect();
                    // importance: 1 - cos(x, x + wo * attn_out)
                    let x = &xs[t * cfg.d_model..(t + 1) * cfg.d_model];
                    let lw = &self.w.layers[layer];
                    let mut delta = vec![0.0; cfg.d_model];
                    matvec_t(&attn[t * nqd..(t + 1) * nqd], &lw.wo, nqd, cfg.d_model, &mut delta);
                    let y: Vec<f32> = x.iter().zip(&delta).map(|(a, b)| a + b).collect();
                    probes[pi].importance[layer] = 1.0 - tensor::cosine_sim(x, &y);
                }
            }
            // residual + MLP
            for t in 0..t_total {
                let x = unsafe {
                    // disjoint ranges of xs; avoids an extra copy per row
                    std::slice::from_raw_parts_mut(
                        xs.as_mut_ptr().add(t * cfg.d_model),
                        cfg.d_model,
                    )
                };
                self.post_row(layer, x, &attn[t * nqd..(t + 1) * nqd]);
            }
        }
        *pos += t_total;
        let last = &xs[(t_total - 1) * cfg.d_model..t_total * cfg.d_model];
        let cap_out = capture.map(|_| CalibrationCapture {
            n_layers: cfg.n_layers,
            n_kv: cfg.n_kv_heads,
            probes,
        });
        (self.logits(last), cap_out)
    }

    /// Run a dense forward and return `layer`'s query vectors
    /// (`[T, n_q * d]`) plus its populated KV cache — the raw material for
    /// pooling-strategy experiments (Fig. 5).
    pub fn capture_layer_qk(&self, tokens: &[u32], layer: usize) -> (Vec<f32>, KvCache) {
        let cfg = &self.cfg;
        let nqd = cfg.n_q_heads * cfg.d_head;
        let t_total = tokens.len();
        let mut xs: Vec<f32> = Vec::with_capacity(t_total * cfg.d_model);
        for &t in tokens {
            xs.extend_from_slice(self.w.embedding(t as usize, cfg.d_model));
        }
        let mut cost = CostTracker::default();
        let mut planes = ScorePlanes::default();
        let mut qbuf = vec![0.0f32; t_total * nqd];
        let mut attn = vec![0.0f32; t_total * nqd];
        let mut k = vec![0.0; cfg.n_kv_heads * cfg.d_head];
        let mut v = vec![0.0; cfg.n_kv_heads * cfg.d_head];
        for l in 0..=layer {
            let mut cache = KvCache::new(cfg.n_kv_heads, cfg.d_head, t_total);
            for t in 0..t_total {
                let x = &xs[t * cfg.d_model..(t + 1) * cfg.d_model];
                let q = &mut qbuf[t * nqd..(t + 1) * nqd];
                self.qkv_row(l, x, t, q, &mut k, &mut v);
                cache.push(&k, &v);
            }
            if l == layer {
                return (qbuf, cache);
            }
            let g = cfg.group();
            attention::prefill_dense_tile(&qbuf, 0, &cache, g, &mut attn, &mut planes, &mut cost);
            for t in 0..t_total {
                let x = unsafe {
                    std::slice::from_raw_parts_mut(
                        xs.as_mut_ptr().add(t * cfg.d_model),
                        cfg.d_model,
                    )
                };
                self.post_row(l, x, &attn[t * nqd..(t + 1) * nqd]);
            }
        }
        unreachable!("layer within range");
    }

    /// One policy-driven decode step.  Returns the next-token logits.
    pub fn decode_step(
        &self,
        token: u32,
        st: &mut SeqState,
        policy: &mut dyn SparsePolicy,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        let nqd = cfg.n_q_heads * cfg.d_head;
        let mut x = self.w.embedding(token as usize, cfg.d_model).to_vec();
        let mut q = vec![0.0; nqd];
        let mut k = vec![0.0; cfg.n_kv_heads * cfg.d_head];
        let mut v = vec![0.0; cfg.n_kv_heads * cfg.d_head];
        let mut attn = vec![0.0; nqd];
        let SeqState { caches, pos, cost, scratch } = st;
        for layer in 0..cfg.n_layers {
            self.qkv_row(layer, &x, *pos, &mut q, &mut k, &mut v);
            caches[layer].push(&k, &v);
            let sel = policy.decode(layer, &q, &caches[layer], cfg.group(), scratch, cost);
            if caches[layer].is_tiered() {
                match sel {
                    Selection::Dense => tier_ok(caches[layer].ensure_all_hot()),
                    Selection::Sparse => tier_ok(caches[layer].ensure_hot_for(&scratch.sel)),
                }
            }
            let cache = &caches[layer];
            let AttnScratch { sel: selset, planes } = scratch;
            match sel {
                Selection::Dense => {
                    attention::decode_dense(&q, cache, cfg.group(), &mut attn, planes, cost)
                }
                Selection::Sparse => {
                    let g = cfg.group();
                    attention::decode_sparse(&q, cache, g, selset, &mut attn, planes, cost)
                }
            }
            self.post_row(layer, &mut x, &attn);
        }
        *pos += 1;
        self.logits(&x)
    }

    /// One step-batched decode pass over `reqs` concurrent sequences,
    /// processed **layer-major over the batch**: per layer, one pass over
    /// each weight matrix serves every sequence's projection / MLP row
    /// (via [`matmul_t`]); then, per layer, the per-sequence work splits
    /// into a *policy phase* (KV append + [`SparsePolicy::decode`],
    /// sharded across sequences) and an *attention phase* (one work item
    /// per `(sequence, KV head)`, each writing its own disjoint output
    /// rows) — both optionally fanned out over `pool`.
    ///
    /// Per-row accumulation order is identical to [`Model::decode_step`],
    /// and each parallel work item is fully self-contained (no
    /// cross-thread reduction; cost shards fold back on the caller in
    /// fixed order), so the logits in `scratch` are **bitwise equal** to
    /// running the sequences one at a time at any thread count.
    ///
    /// All staging lives in the caller's [`BatchScratch`], and each
    /// sequence's score planes live in its own [`SeqState::scratch`]:
    /// with `pool == None` the steady-state call performs **zero heap
    /// allocations** (asserted by `tests/alloc_steady_state.rs`).  The
    /// parallel path allocates only the per-layer job boxes.
    /// Read row `i`'s logits via [`BatchScratch::logits_row`].
    // analyze: hot-path
    pub fn decode_batch(
        &self,
        reqs: &mut [DecodeReq],
        scratch: &mut BatchScratch,
        pool: Option<&WorkerPool>,
    ) {
        let b = reqs.len();
        let cfg = &self.cfg;
        scratch.vocab = cfg.vocab;
        if b == 0 {
            scratch.logits.clear();
            return;
        }
        let dm = cfg.d_model;
        let nqd = cfg.n_q_heads * cfg.d_head;
        let nkd = cfg.n_kv_heads * cfg.d_head;
        let n_kv = cfg.n_kv_heads;
        let g = cfg.group();
        let gd = g * cfg.d_head;
        let threads = pool.map(|p| p.size()).unwrap_or(1).max(1);
        scratch.ensure(cfg, b, threads);
        let BatchScratch {
            xs,
            h,
            q,
            k,
            v,
            attn,
            delta,
            a,
            bb,
            logits,
            sels,
            head_costs,
            job_planes,
            ..
        } = scratch;
        xs.clear();
        for r in reqs.iter() {
            xs.extend_from_slice(self.w.embedding(r.token as usize, dm));
        }
        for layer in 0..cfg.n_layers {
            let lw = &self.w.layers[layer];
            // batched QKV projection: one pass over wq/wk/wv for all rows
            for i in 0..b {
                rmsnorm(&xs[i * dm..(i + 1) * dm], &lw.ln1, &mut h[i * dm..(i + 1) * dm]);
            }
            matmul_t(h, &lw.wq, b, dm, nqd, q);
            matmul_t(h, &lw.wk, b, dm, nkd, k);
            matmul_t(h, &lw.wv, b, dm, nkd, v);
            if cfg.rope {
                for (i, r) in reqs.iter().enumerate() {
                    let pos = r.st.pos;
                    for hq in 0..cfg.n_q_heads {
                        let o = i * nqd + hq * cfg.d_head;
                        rope(&mut q[o..o + cfg.d_head], pos, cfg.rope_theta);
                    }
                    for hk in 0..cfg.n_kv_heads {
                        let o = i * nkd + hk * cfg.d_head;
                        rope(&mut k[o..o + cfg.d_head], pos, cfg.rope_theta);
                    }
                }
            }
            // --- policy phase: per-sequence KV append + sparse decision,
            // sharded across sequences (each touches only its own state)
            if threads <= 1 || b == 1 {
                for (i, (r, sel)) in reqs.iter_mut().zip(sels.iter_mut()).enumerate() {
                    *sel = policy_phase(r, i, layer, g, q, k, v, nqd, nkd);
                }
            } else {
                let chunk = b.div_ceil(threads);
                let (q2, k2, v2): (&[f32], &[f32], &[f32]) = (&q[..], &k[..], &v[..]);
                let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(threads);
                for (ci, (rc, sc)) in
                    reqs.chunks_mut(chunk).zip(sels.chunks_mut(chunk)).enumerate()
                {
                    let base = ci * chunk;
                    // analyze: allow(hot-path-alloc) — per-layer job boxes, bounded by thread count
                    jobs.push(Box::new(move || {
                        for (j, (r, sel)) in rc.iter_mut().zip(sc.iter_mut()).enumerate() {
                            *sel = policy_phase(r, base + j, layer, g, q2, k2, v2, nqd, nkd);
                        }
                    }));
                }
                pool.expect("threads > 1 implies pool").run(jobs);
            }
            // --- attention phase: one self-contained work item per
            // (sequence, KV head), each with disjoint output rows
            if threads <= 1 {
                let planes = &mut job_planes[0];
                for (i, r) in reqs.iter_mut().enumerate() {
                    let st = &mut *r.st;
                    let cache = &st.caches[layer];
                    let qrow = &q[i * nqd..(i + 1) * nqd];
                    let out = &mut attn[i * nqd..(i + 1) * nqd];
                    match sels[i] {
                        Selection::Dense => {
                            attention::decode_dense(qrow, cache, g, out, planes, &mut st.cost)
                        }
                        Selection::Sparse => {
                            let sel = &st.scratch.sel;
                            attention::decode_sparse(qrow, cache, g, sel, out, planes, &mut st.cost)
                        }
                    }
                }
            } else {
                for c in head_costs[..b * n_kv].iter_mut() {
                    *c = CostTracker::default();
                }
                let mut items: Vec<HeadItem<'_>> = Vec::with_capacity(b * n_kv);
                {
                    let mut outs = attn[..b * nqd].chunks_mut(gd);
                    let mut costs = head_costs[..b * n_kv].iter_mut();
                    for (i, r) in reqs.iter().enumerate() {
                        let st: &SeqState = &*r.st;
                        let cache = &st.caches[layer];
                        let qrow = &q[i * nqd..(i + 1) * nqd];
                        let sel = match sels[i] {
                            Selection::Dense => None,
                            Selection::Sparse => Some(&st.scratch.sel),
                        };
                        for hh in 0..n_kv {
                            // analyze: allow(hot-path-alloc) — work-item list into with_capacity(b*n_kv)
                            items.push(HeadItem {
                                cache,
                                qrow,
                                sel,
                                h: hh,
                                out: outs.next().expect("attn sized b*nqd"),
                                cost: costs.next().expect("head_costs sized b*n_kv"),
                            });
                        }
                    }
                }
                let per = items.len().div_ceil(threads);
                let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(threads);
                for (chunk, planes) in items.chunks_mut(per).zip(job_planes.iter_mut()) {
                    // analyze: allow(hot-path-alloc) — per-layer job boxes, bounded by thread count
                    jobs.push(Box::new(move || {
                        for it in chunk.iter_mut() {
                            match it.sel {
                                None => attention::decode_dense_head(
                                    it.qrow,
                                    it.h,
                                    it.cache.len,
                                    it.cache,
                                    g,
                                    it.out,
                                    planes,
                                    it.cost,
                                ),
                                Some(s) => attention::decode_sparse_head(
                                    it.qrow,
                                    it.h,
                                    s.head(it.h),
                                    it.cache,
                                    g,
                                    it.out,
                                    planes,
                                    it.cost,
                                ),
                            }
                        }
                    }));
                }
                pool.expect("threads > 1 implies pool").run(jobs);
                drop(items);
                // fold the per-(sequence, head) cost shards back, fixed order
                for (i, r) in reqs.iter_mut().enumerate() {
                    for hh in 0..n_kv {
                        r.st.cost.merge(&head_costs[i * n_kv + hh]);
                    }
                }
            }
            // batched residual write + SwiGLU MLP
            matmul_t(attn, &lw.wo, b, nqd, dm, delta);
            for (xi, di) in xs.iter_mut().zip(delta.iter()) {
                *xi += di;
            }
            for i in 0..b {
                rmsnorm(&xs[i * dm..(i + 1) * dm], &lw.ln2, &mut h[i * dm..(i + 1) * dm]);
            }
            matmul_t(h, &lw.w1, b, dm, cfg.d_ff, a);
            matmul_t(h, &lw.w3, b, dm, cfg.d_ff, bb);
            for (ai, bi) in a.iter_mut().zip(bb.iter()) {
                let s = *ai / (1.0 + (-*ai).exp()); // silu
                *ai = s * bi;
            }
            matmul_t(a, &lw.w2, b, cfg.d_ff, dm, delta);
            for (xi, di) in xs.iter_mut().zip(delta.iter()) {
                *xi += di;
            }
        }
        for r in reqs.iter_mut() {
            r.st.pos += 1;
        }
        // batched unembedding into the scratch's logits plane
        for i in 0..b {
            rmsnorm(&xs[i * dm..(i + 1) * dm], &self.w.lnf, &mut h[i * dm..(i + 1) * dm]);
        }
        matmul_t(h, &self.w.w_u, b, dm, cfg.vocab, logits);
    }

    /// Greedy decode until `stop(token)` or `max_new` tokens.
    /// Returns the emitted tokens.
    pub fn greedy_decode(
        &self,
        first_logits: &[f32],
        st: &mut SeqState,
        policy: &mut dyn SparsePolicy,
        max_new: usize,
        stop: impl Fn(u32) -> bool,
    ) -> Vec<u32> {
        self.sample_decode(
            first_logits,
            st,
            policy,
            max_new,
            &crate::config::SamplingParams::Greedy,
            stop,
        )
    }

    /// Decode loop under a typed [`crate::config::SamplingParams`] — the
    /// same rule the serving engine applies per token, keyed by response
    /// position, so a standalone loop and a served request with the same
    /// seed emit identical streams.  `Greedy` reproduces `greedy_decode`
    /// exactly.
    pub fn sample_decode(
        &self,
        first_logits: &[f32],
        st: &mut SeqState,
        policy: &mut dyn SparsePolicy,
        max_new: usize,
        sampling: &crate::config::SamplingParams,
        stop: impl Fn(u32) -> bool,
    ) -> Vec<u32> {
        let mut out = Vec::new();
        let mut tok = sampling.sample(first_logits, 0);
        out.push(tok);
        while out.len() < max_new && !stop(tok) {
            let logits = self.decode_step(tok, st, policy);
            tok = sampling.sample(&logits, out.len());
            out.push(tok);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::DensePolicy;
    use crate::tensor::Rng;

    fn random_model(seed: u64) -> Model {
        let cfg = ModelConfig {
            n_layers: 2,
            d_model: 32,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 64,
            rope_theta: 10000.0,
            rope: true,
        };
        let mut w = Weights::zeros(&cfg);
        let mut r = Rng::new(seed);
        r.fill_normal(&mut w.w_e, 0.3);
        for lw in &mut w.layers {
            r.fill_normal(&mut lw.wq, 0.18);
            r.fill_normal(&mut lw.wk, 0.18);
            r.fill_normal(&mut lw.wv, 0.18);
            r.fill_normal(&mut lw.wo, 0.18);
            r.fill_normal(&mut lw.w1, 0.18);
            r.fill_normal(&mut lw.w3, 0.18);
            r.fill_normal(&mut lw.w2, 0.12);
        }
        r.fill_normal(&mut w.w_u, 0.18);
        Model::new(cfg, w)
    }

    /// The core consistency invariant: prefilling N tokens must produce the
    /// same logits as prefilling N-1 then decoding token N-1.
    #[test]
    fn prefill_decode_consistency() {
        let m = random_model(1);
        let mut r = Rng::new(2);
        let toks: Vec<u32> = (0..20).map(|_| r.below(64) as u32).collect();

        let mut st_full = m.new_state(64);
        let (logits_full, _) = m.prefill(&toks, &mut st_full, &mut DensePolicy, None);

        let mut st_inc = m.new_state(64);
        let (_, _) = m.prefill(&toks[..19], &mut st_inc, &mut DensePolicy, None);
        let logits_inc = m.decode_step(toks[19], &mut st_inc, &mut DensePolicy);

        for (a, b) in logits_full.iter().zip(&logits_inc) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(st_full.pos, 20);
        assert_eq!(st_inc.pos, 20);
    }

    /// Chunked prefill (two chunks) must equal single-shot prefill.
    #[test]
    fn chunked_prefill_consistency() {
        let m = random_model(3);
        let mut r = Rng::new(4);
        let toks: Vec<u32> = (0..160).map(|_| r.below(64) as u32).collect();

        let mut st_a = m.new_state(256);
        let (la, _) = m.prefill(&toks, &mut st_a, &mut DensePolicy, None);
        let mut st_b = m.new_state(256);
        m.prefill(&toks[..100], &mut st_b, &mut DensePolicy, None);
        let (lb, _) = m.prefill(&toks[100..], &mut st_b, &mut DensePolicy, None);
        for (a, b) in la.iter().zip(&lb) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn capture_produces_probe_distributions() {
        let m = random_model(5);
        let mut r = Rng::new(6);
        let toks: Vec<u32> = (0..32).map(|_| r.below(64) as u32).collect();
        let mut st = m.new_state(64);
        let req = CaptureRequest { probe_positions: vec![10, 31] };
        let (_, cap) = m.prefill(&toks, &mut st, &mut DensePolicy, Some(&req));
        let cap = cap.unwrap();
        assert_eq!(cap.probes.len(), 2);
        for (pi, pp) in [(0usize, 10usize), (1, 31)] {
            for l in 0..2 {
                let dists = &cap.probes[pi].dists[l];
                assert_eq!(dists.len(), 2); // n_kv
                for d in dists {
                    assert_eq!(d.len(), pp + 1);
                    let s: f32 = d.iter().sum();
                    assert!((s - 1.0).abs() < 1e-3);
                }
                let imp = cap.probes[pi].importance[l];
                assert!((0.0..=2.0).contains(&imp));
            }
        }
    }

    /// The tentpole invariant: a step-batched decode pass must produce
    /// logits **bitwise equal** to decoding each sequence alone.
    #[test]
    fn decode_batch_bitwise_equals_decode_step() {
        use crate::config::TopKRule;
        use crate::kascade::KascadePlan;
        use crate::sparse::KascadePolicy;

        let m = random_model(11);
        let mut r = Rng::new(12);
        let mut scratch = BatchScratch::new();
        for bsz in [1usize, 2, 5, 8] {
            // per-sequence prompts of different lengths, mixed policies
            let mut seq_sts = Vec::new();
            let mut seq_pols: Vec<Box<dyn crate::sparse::SparsePolicy>> = Vec::new();
            let mut bat_sts = Vec::new();
            let mut bat_pols: Vec<Box<dyn crate::sparse::SparsePolicy>> = Vec::new();
            let mut last_toks = Vec::new();
            for i in 0..bsz {
                let plen = 4 + r.below(24);
                let toks: Vec<u32> = (0..plen).map(|_| r.below(64) as u32).collect();
                let mk_pol = |i: usize| -> Box<dyn crate::sparse::SparsePolicy> {
                    if i % 2 == 0 {
                        Box::new(DensePolicy)
                    } else {
                        Box::new(KascadePolicy::new(KascadePlan::from_anchors(
                            2,
                            2,
                            vec![0],
                            TopKRule::new(0.5, 4),
                        )))
                    }
                };
                let mut st_a = m.new_state(128);
                let mut pol_a = mk_pol(i);
                m.prefill(&toks, &mut st_a, pol_a.as_mut(), None);
                let mut st_b = m.new_state(128);
                let mut pol_b = mk_pol(i);
                m.prefill(&toks, &mut st_b, pol_b.as_mut(), None);
                seq_sts.push(st_a);
                seq_pols.push(pol_a);
                bat_sts.push(st_b);
                bat_pols.push(pol_b);
                last_toks.push(r.below(64) as u32);
            }
            for _step in 0..4 {
                // sequential reference
                let mut seq_logits = Vec::new();
                for i in 0..bsz {
                    seq_logits.push(m.decode_step(
                        last_toks[i],
                        &mut seq_sts[i],
                        seq_pols[i].as_mut(),
                    ));
                }
                // batched
                let mut reqs: Vec<DecodeReq> = bat_sts
                    .iter_mut()
                    .zip(bat_pols.iter_mut())
                    .zip(last_toks.iter())
                    .map(|((st, pol), &token)| DecodeReq { token, st, policy: pol.as_mut() })
                    .collect();
                m.decode_batch(&mut reqs, &mut scratch, None);
                drop(reqs);
                for i in 0..bsz {
                    let row = scratch.logits_row(i);
                    for (a, b) in seq_logits[i].iter().zip(row) {
                        assert_eq!(a.to_bits(), b.to_bits(), "bsz={bsz} seq={i}");
                    }
                    last_toks[i] = tensor::argmax(row) as u32;
                }
            }
        }
    }

    /// Parallel decode_batch (worker pool, sequence + KV-head sharding)
    /// must be bitwise-identical to the serial path: every work item is
    /// self-contained and cost shards fold back in fixed order.
    #[test]
    fn decode_batch_parallel_bitwise_equals_serial() {
        use crate::config::TopKRule;
        use crate::kascade::KascadePlan;
        use crate::pool::WorkerPool;
        use crate::sparse::KascadePolicy;

        let m = random_model(31);
        let mut r = Rng::new(32);
        for threads in [2usize, 4] {
            let pool = WorkerPool::new(threads);
            let bsz = 5usize;
            let mk_pol = |i: usize| -> Box<dyn crate::sparse::SparsePolicy> {
                if i % 2 == 0 {
                    Box::new(DensePolicy)
                } else {
                    Box::new(KascadePolicy::new(KascadePlan::from_anchors(
                        2,
                        2,
                        vec![0],
                        TopKRule::new(0.5, 4),
                    )))
                }
            };
            let mut ser_sts = Vec::new();
            let mut ser_pols: Vec<Box<dyn crate::sparse::SparsePolicy>> = Vec::new();
            let mut par_sts = Vec::new();
            let mut par_pols: Vec<Box<dyn crate::sparse::SparsePolicy>> = Vec::new();
            let mut toks = Vec::new();
            for i in 0..bsz {
                let plen = 6 + r.below(20);
                let prompt: Vec<u32> = (0..plen).map(|_| r.below(64) as u32).collect();
                let mut st_a = m.new_state(96);
                let mut pol_a = mk_pol(i);
                m.prefill(&prompt, &mut st_a, pol_a.as_mut(), None);
                let mut st_b = m.new_state(96);
                let mut pol_b = mk_pol(i);
                m.prefill(&prompt, &mut st_b, pol_b.as_mut(), None);
                ser_sts.push(st_a);
                ser_pols.push(pol_a);
                par_sts.push(st_b);
                par_pols.push(pol_b);
                toks.push(r.below(64) as u32);
            }
            let mut ser_scr = BatchScratch::new();
            let mut par_scr = BatchScratch::new();
            for _step in 0..5 {
                let mut ser_reqs: Vec<DecodeReq> = ser_sts
                    .iter_mut()
                    .zip(ser_pols.iter_mut())
                    .zip(toks.iter())
                    .map(|((st, pol), &token)| DecodeReq { token, st, policy: pol.as_mut() })
                    .collect();
                m.decode_batch(&mut ser_reqs, &mut ser_scr, None);
                drop(ser_reqs);
                let mut par_reqs: Vec<DecodeReq> = par_sts
                    .iter_mut()
                    .zip(par_pols.iter_mut())
                    .zip(toks.iter())
                    .map(|((st, pol), &token)| DecodeReq { token, st, policy: pol.as_mut() })
                    .collect();
                m.decode_batch(&mut par_reqs, &mut par_scr, Some(&pool));
                drop(par_reqs);
                for i in 0..bsz {
                    let (a, b) = (ser_scr.logits_row(i), par_scr.logits_row(i));
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "threads={threads} seq={i}");
                    }
                    toks[i] = tensor::argmax(a) as u32;
                }
            }
            // cost accounting identical too (shards merged in fixed order)
            for (a, b) in ser_sts.iter().zip(&par_sts) {
                assert_eq!(a.cost.score_key_reads, b.cost.score_key_reads);
                assert_eq!(a.cost.attend_kv_reads, b.cost.attend_kv_reads);
                assert_eq!(a.cost.topk_items, b.cost.topk_items);
                assert_eq!(a.pos, b.pos);
            }
        }
    }

    /// Chunked prefill with a Kascade policy must match single-shot
    /// prefill: prefill Top-k state is keyed by absolute tile, so a reuse
    /// layer consumes exactly what its anchor produced for each tile
    /// regardless of chunk boundaries.
    #[test]
    fn chunked_prefill_kascade_consistency() {
        use crate::config::TopKRule;
        use crate::kascade::KascadePlan;
        use crate::sparse::KascadePolicy;

        let m = random_model(21);
        let mut r = Rng::new(22);
        let toks: Vec<u32> = (0..384).map(|_| r.below(64) as u32).collect();
        // layer 0 anchors, layer 1 reuses — the cross-chunk state path
        let plan = KascadePlan::from_anchors(2, 2, vec![0], TopKRule::new(0.25, 16));

        let mut st_a = m.new_state(512);
        let mut pol_a = KascadePolicy::new(plan.clone());
        let (la, _) = m.prefill(&toks, &mut st_a, &mut pol_a, None);

        let mut st_b = m.new_state(512);
        let mut pol_b = KascadePolicy::new(plan);
        m.prefill(&toks[..128], &mut st_b, &mut pol_b, None);
        m.prefill(&toks[128..256], &mut st_b, &mut pol_b, None);
        let (lb, _) = m.prefill(&toks[256..], &mut st_b, &mut pol_b, None);

        for (a, b) in la.iter().zip(&lb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn greedy_decode_stops_on_stop_token() {
        let m = random_model(7);
        let mut st = m.new_state(64);
        let (logits, _) = m.prefill(&[1, 2, 3], &mut st, &mut DensePolicy, None);
        let first = crate::tensor::argmax(&logits) as u32;
        let out = m.greedy_decode(&logits, &mut st, &mut DensePolicy, 10, |t| t == first);
        assert_eq!(out, vec![first]); // stop() true on the very first token
    }

    #[test]
    fn sample_decode_greedy_matches_greedy_decode_and_seeds_replay() {
        use crate::config::SamplingParams;
        let m = random_model(13);
        let run = |sampling: &SamplingParams| -> Vec<u32> {
            let mut st = m.new_state(64);
            let (logits, _) = m.prefill(&[1, 2, 3, 4], &mut st, &mut DensePolicy, None);
            m.sample_decode(&logits, &mut st, &mut DensePolicy, 8, sampling, |_| false)
        };
        let greedy = {
            let mut st = m.new_state(64);
            let (logits, _) = m.prefill(&[1, 2, 3, 4], &mut st, &mut DensePolicy, None);
            m.greedy_decode(&logits, &mut st, &mut DensePolicy, 8, |_| false)
        };
        assert_eq!(run(&SamplingParams::Greedy), greedy);
        let seeded = SamplingParams::seeded(0xFEED).temperature(1.5);
        assert_eq!(run(&seeded), run(&seeded), "seeded decode must replay exactly");
    }
}
