//! Runtime-dispatched SIMD kernels for the tile-major attention hot paths.
//!
//! Every kernel here is a **bitwise-faithful** vector replication of its
//! scalar counterpart in [`crate::tensor`]:
//!
//! * **Reductions** (`dot`, `sum4`, `dot_i8`, `qk_dot_q8`, `dot_f16`,
//!   `dot_i4`, `qk_dot_q4`) are pinned to the scalar kernels' 4-lane
//!   accumulator structure: lane `j` accumulates exactly the elements
//!   scalar accumulator `acc[j]` would, multiplies and adds are separate
//!   instructions (**no FMA** — fused rounding would diverge), the
//!   horizontal sum stores the lanes and folds them in the scalar order
//!   `((l0 + l1) + l2) + l3`, and the ragged tail runs the scalar loop.
//!   Wider machines (AVX2) still run these reductions at 4 lanes — the
//!   bitwise contract is worth more than the last 2x of a bandwidth-bound
//!   loop, and it is what lets `attention::reference` stay an exact
//!   oracle for every dtype (see `docs/perf.md` for the derivation).
//! * **Elementwise kernels** (`axpy`, `axpy_q8`, `axpy_f16`, `axpy_q4`,
//!   `scale_in_place`, the `softmax` rescale) have no cross-lane
//!   dependency at all, so they may run at any width (8 lanes on AVX2)
//!   and remain bitwise-identical by construction.
//! * **Integer widening** (i8 -> i32 -> f32, nibble -> i8 -> i32 -> f32)
//!   and f16 -> f32 conversion are exact in both the scalar and hardware
//!   paths (every such value is representable), so quantized operands
//!   introduce no level-dependent rounding.
//!
//! The level is selected **once** per process via [`detect`] (cached in a
//! `OnceLock`) and stamped into each `KvCache` at construction — never
//! re-probed per tile.  `KASCADE_FORCE_SCALAR=1` forces the scalar
//! fallback (the CI forced-fallback leg), and Miri always gets scalar
//! because it does not model vendor intrinsics.
//!
//! | level  | arch    | f32 lanes | int8/f16/int4 codes          |
//! |--------|---------|-----------|------------------------------|
//! | Scalar | any     | scalar    | scalar                       |
//! | Sse2   | x86_64  | 4 (SSE2)  | scalar (widen needs SSE4.1)  |
//! | Avx2   | x86_64  | 4/8       | 4-lane widen, F16C converts  |
//! | Neon   | aarch64 | 4 (NEON)  | scalar (pending hw to validate) |

use crate::tensor;
use std::sync::OnceLock;

/// Vector instruction level, resolved once per process by [`detect`].
/// All variants exist on every arch (so tests and bench tables can name
/// them portably); a level that is not native to the current arch simply
/// dispatches to the scalar kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdLevel {
    /// Portable scalar kernels in `tensor.rs` — the oracle everything
    /// else must match bitwise.
    #[default]
    Scalar,
    /// x86_64 baseline: 4-lane f32 SSE2.  Quantized-code kernels stay
    /// scalar (the i8 widen `_mm_cvtepi8_epi32` needs SSE4.1).
    Sse2,
    /// x86_64 with AVX2 + SSE4.1 + F16C: 4-lane reductions for every
    /// dtype, 8-lane elementwise kernels, hardware f16 conversion.
    Avx2,
    /// aarch64 baseline NEON: 4-lane f32; code kernels stay scalar until
    /// the paths can be validated on real hardware (CI cross-checks the
    /// build only).
    Neon,
}

impl SimdLevel {
    /// Short lowercase label for bench tables and logs.
    pub fn label(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// True for any level that engages vector instructions.
    pub fn is_simd(&self) -> bool {
        !matches!(self, SimdLevel::Scalar)
    }
}

/// Probe the host (no env override, no cache) — the raw arch detection
/// behind [`detect`] and [`available_levels`].
fn detect_arch() -> SimdLevel {
    if cfg!(miri) {
        // Miri does not model vendor intrinsics; the scalar kernels are
        // the semantics anyway.
        return SimdLevel::Scalar;
    }
    arch_probe()
}

#[cfg(target_arch = "x86_64")]
fn arch_probe() -> SimdLevel {
    // Avx2 bundles every feature its kernels use; a machine with AVX2
    // but not F16C (vanishingly rare) degrades to Sse2 rather than
    // splitting the level semantics per dtype.
    if is_x86_feature_detected!("avx2")
        && is_x86_feature_detected!("sse4.1")
        && is_x86_feature_detected!("f16c")
    {
        SimdLevel::Avx2
    } else {
        SimdLevel::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn arch_probe() -> SimdLevel {
    SimdLevel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn arch_probe() -> SimdLevel {
    SimdLevel::Scalar
}

/// The process-wide SIMD level: arch detection run **once** (cached), with
/// `KASCADE_FORCE_SCALAR` (any value but `0`/empty) forcing [`SimdLevel::Scalar`]
/// — the CI forced-fallback leg and the escape hatch for bisecting any
/// suspected vector-path miscompile.  `KvCache` stamps this at
/// construction; kernels never re-probe per tile.
pub fn detect() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let forced = std::env::var("KASCADE_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if forced {
            SimdLevel::Scalar
        } else {
            detect_arch()
        }
    })
}

/// Every level the current host can actually execute, scalar first —
/// the iteration domain for the `simd == scalar` property suites.
/// Ignores the `KASCADE_FORCE_SCALAR` override: tests pass levels
/// explicitly, the override only pins what [`detect`] hands the engine.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut v = vec![SimdLevel::Scalar];
    match detect_arch() {
        SimdLevel::Avx2 => {
            v.push(SimdLevel::Sse2);
            v.push(SimdLevel::Avx2);
        }
        SimdLevel::Sse2 => v.push(SimdLevel::Sse2),
        SimdLevel::Neon => v.push(SimdLevel::Neon),
        SimdLevel::Scalar => {}
    }
    v
}

// ---------------------------------------------------------------------------
// dispatchers — one per tile-kernel primitive
// ---------------------------------------------------------------------------

/// f32 dot product; bitwise-equal to [`tensor::dot`] at every level.
// analyze: hot-path
#[inline]
pub fn dot(level: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 | SimdLevel::Avx2 => x86::dot_sse2(a, b),
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::dot_neon(a, b),
        _ => tensor::dot(a, b),
    }
}

/// 4-lane element sum; bitwise-equal to [`tensor::sum4`] at every level.
// analyze: hot-path
#[inline]
pub fn sum4(level: SimdLevel, a: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 | SimdLevel::Avx2 => x86::sum4_sse2(a),
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::sum4_neon(a),
        _ => tensor::sum4(a),
    }
}

/// `y += a * x`; elementwise, bitwise-equal to [`tensor::axpy`].
// analyze: hot-path
#[inline]
pub fn axpy(level: SimdLevel, y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => x86::axpy_sse2(y, a, x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::axpy_avx2(y, a, x) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::axpy_neon(y, a, x),
        _ => tensor::axpy(y, a, x),
    }
}

/// f32 x int8 raw dot; bitwise-equal to [`tensor::dot_i8`].
// analyze: hot-path
#[inline]
pub fn dot_i8(level: SimdLevel, a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::dot_i8_sse41(a, q) },
        _ => tensor::dot_i8(a, q),
    }
}

/// Fused f32 x int8 affine dot; bitwise-equal to [`tensor::qk_dot_q8`].
// analyze: hot-path
#[inline]
pub fn qk_dot_q8(level: SimdLevel, a: &[f32], q: &[i8], scale: f32, zero: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::qk_dot_q8_sse41(a, q, scale, zero) },
        _ => tensor::qk_dot_q8(a, q, scale, zero),
    }
}

/// Fused `y += w * (scale * q + zero)` over int8 codes; elementwise,
/// bitwise-equal to [`tensor::axpy_q8`].
// analyze: hot-path
#[inline]
pub fn axpy_q8(level: SimdLevel, y: &mut [f32], w: f32, q: &[i8], scale: f32, zero: f32) {
    debug_assert_eq!(y.len(), q.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::axpy_q8_avx2(y, w, q, scale, zero) },
        _ => tensor::axpy_q8(y, w, q, scale, zero),
    }
}

/// f32 x f16 dot with f32 accumulation; bitwise-equal to
/// [`tensor::dot_f16`] (hardware F16C conversion computes the identical
/// bits to the software converter — f16 -> f32 is exact).
// analyze: hot-path
#[inline]
pub fn dot_f16(level: SimdLevel, a: &[f32], h: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), h.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::dot_f16_f16c(a, h) },
        _ => tensor::dot_f16(a, h),
    }
}

/// `y += w * h` over an f16 row; elementwise, bitwise-equal to
/// [`tensor::axpy_f16`].
// analyze: hot-path
#[inline]
pub fn axpy_f16(level: SimdLevel, y: &mut [f32], w: f32, h: &[u16]) {
    debug_assert_eq!(y.len(), h.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::axpy_f16_f16c(y, w, h) },
        _ => tensor::axpy_f16(y, w, h),
    }
}

/// f32 x packed-int4 raw dot; bitwise-equal to [`tensor::dot_i4`].
// analyze: hot-path
#[inline]
pub fn dot_i4(level: SimdLevel, a: &[f32], q: &[u8]) -> f32 {
    debug_assert_eq!(a.len(), q.len() * 2);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::dot_i4_sse41(a, q) },
        _ => tensor::dot_i4(a, q),
    }
}

/// Fused f32 x packed-int4 affine dot; bitwise-equal to
/// [`tensor::qk_dot_q4`].
// analyze: hot-path
#[inline]
pub fn qk_dot_q4(level: SimdLevel, a: &[f32], q: &[u8], scale: f32, zero: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len() * 2);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::qk_dot_q4_sse41(a, q, scale, zero) },
        _ => tensor::qk_dot_q4(a, q, scale, zero),
    }
}

/// Fused `y += w * (scale * q + zero)` over packed int4 codes;
/// elementwise, bitwise-equal to [`tensor::axpy_q4`].
// analyze: hot-path
#[inline]
pub fn axpy_q4(level: SimdLevel, y: &mut [f32], w: f32, q: &[u8], scale: f32, zero: f32) {
    debug_assert_eq!(y.len(), q.len() * 2);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::axpy_q4_avx2(y, w, q, scale, zero) },
        _ => tensor::axpy_q4(y, w, q, scale, zero),
    }
}

/// Elementwise in-place scale `x *= s` — the softmax rescale inner loop.
/// Elementwise, so bitwise-identical at any lane width.
// analyze: hot-path
#[inline]
pub fn scale_in_place(level: SimdLevel, xs: &mut [f32], s: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => x86::scale_sse2(xs, s),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detect() only yields Avx2 when avx2/sse4.1/f16c are present.
        SimdLevel::Avx2 => unsafe { x86::scale_avx2(xs, s) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::scale_neon(xs, s),
        _ => {
            for x in xs.iter_mut() {
                *x *= s;
            }
        }
    }
}

/// In-place numerically-stable softmax, bitwise-equal to
/// [`tensor::softmax`] at every level: the max fold and the exp/sum pass
/// stay scalar (their sequential accumulation order is part of the
/// bitwise contract), only the elementwise `x *= 1/z` rescale dispatches.
// analyze: hot-path
pub fn softmax(level: SimdLevel, s: &mut [f32]) -> f32 {
    let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        s.fill(0.0);
        return m;
    }
    let mut z = 0.0;
    for x in s.iter_mut() {
        *x = (*x - m).exp();
        z += *x;
    }
    scale_in_place(level, s, 1.0 / z);
    m
}

/// Top-k partial select, identical index selection to
/// [`tensor::topk_unordered_into`] at every level: the `(value, index)`
/// staging fill is the only lane-parallel phase (a memory-bound
/// streaming write LLVM already vectorizes from this shape — and
/// `(f32, u32)` tuple layout is unspecified, so explicit vector stores
/// into the pairs buffer would not be sound), while the quickselect swap
/// chain is data-dependent and stays scalar by design, preserving the
/// exact deterministic pivot sequence the selection tests assert.
// analyze: hot-path
pub fn topk_into(
    level: SimdLevel,
    vals: &[f32],
    k: usize,
    pairs: &mut Vec<(f32, u32)>,
    out: &mut Vec<u32>,
) {
    let _ = level;
    let n = vals.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n as u32);
        return;
    }
    pairs.clear();
    pairs.extend(vals.iter().copied().zip(0..n as u32));
    tensor::topk_prestaged(pairs, n, k, out);
}

// ---------------------------------------------------------------------------
// x86_64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use crate::tensor;
    use core::arch::x86_64::*;

    // SSE2 is part of the x86_64 baseline, so these first four need no
    // runtime gate and no #[target_feature] — plain fns with internal
    // unsafe blocks for the loads/stores.

    #[inline]
    pub(super) fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let vb = _mm_loadu_ps(b.as_ptr().add(i * 4));
                // mul then add as separate instructions — FMA's fused
                // rounding would diverge from the scalar kernel
                acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        // horizontal fold in the scalar kernel's order, then scalar tail
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    #[inline]
    pub(super) fn sum4_sse2(a: &[f32]) -> f32 {
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                acc = _mm_add_ps(acc, _mm_loadu_ps(a.as_ptr().add(i * 4)));
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for &x in &a[chunks * 4..] {
            s += x;
        }
        s
    }

    #[inline]
    pub(super) fn axpy_sse2(y: &mut [f32], a: f32, x: &[f32]) {
        let chunks = y.len() / 4;
        unsafe {
            let va = _mm_set1_ps(a);
            for i in 0..chunks {
                let vy = _mm_loadu_ps(y.as_ptr().add(i * 4));
                let vx = _mm_loadu_ps(x.as_ptr().add(i * 4));
                let t = _mm_add_ps(vy, _mm_mul_ps(va, vx));
                _mm_storeu_ps(y.as_mut_ptr().add(i * 4), t);
            }
        }
        for i in chunks * 4..y.len() {
            y[i] += a * x[i];
        }
    }

    #[inline]
    pub(super) fn scale_sse2(xs: &mut [f32], s: f32) {
        let chunks = xs.len() / 4;
        unsafe {
            let vs = _mm_set1_ps(s);
            for i in 0..chunks {
                let v = _mm_loadu_ps(xs.as_ptr().add(i * 4));
                _mm_storeu_ps(xs.as_mut_ptr().add(i * 4), _mm_mul_ps(v, vs));
            }
        }
        for x in &mut xs[chunks * 4..] {
            *x *= s;
        }
    }

    // The Avx2-level kernels.  All carry the full feature bundle the
    // level guarantees; callers gate on `detect() == Avx2`.

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn axpy_avx2(y: &mut [f32], a: f32, x: &[f32]) {
        // Elementwise: 8 lanes are bitwise-safe (no cross-lane sums).
        let chunks = y.len() / 8;
        unsafe {
            let va = _mm256_set1_ps(a);
            for i in 0..chunks {
                let vy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
                let vx = _mm256_loadu_ps(x.as_ptr().add(i * 8));
                let t = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
                _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), t);
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * x[i];
        }
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn scale_avx2(xs: &mut [f32], s: f32) {
        let chunks = xs.len() / 8;
        unsafe {
            let vs = _mm256_set1_ps(s);
            for i in 0..chunks {
                let v = _mm256_loadu_ps(xs.as_ptr().add(i * 8));
                _mm256_storeu_ps(xs.as_mut_ptr().add(i * 8), _mm256_mul_ps(v, vs));
            }
        }
        for x in &mut xs[chunks * 8..] {
            *x *= s;
        }
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn dot_i8_sse41(a: &[f32], q: &[i8]) -> f32 {
        // 4-lane: i8 -> i32 -> f32 widening is exact, accumulation
        // structure matches tensor::dot_i8's sq lanes.
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let w = (q.as_ptr().add(i * 4) as *const i32).read_unaligned();
                let vq = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(_mm_cvtsi32_si128(w)));
                acc = _mm_add_ps(acc, _mm_mul_ps(va, vq));
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        let mut dq = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in chunks * 4..a.len() {
            dq += a[i] * q[i] as f32;
        }
        dq
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn qk_dot_q8_sse41(a: &[f32], q: &[i8], scale: f32, zero: f32) -> f32 {
        let chunks = a.len() / 4;
        let mut ql = [0.0f32; 4];
        let mut al = [0.0f32; 4];
        unsafe {
            let mut accq = _mm_setzero_ps();
            let mut acca = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let w = (q.as_ptr().add(i * 4) as *const i32).read_unaligned();
                let vq = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(_mm_cvtsi32_si128(w)));
                accq = _mm_add_ps(accq, _mm_mul_ps(va, vq));
                acca = _mm_add_ps(acca, va);
            }
            _mm_storeu_ps(ql.as_mut_ptr(), accq);
            _mm_storeu_ps(al.as_mut_ptr(), acca);
        }
        let mut dq = ql[0] + ql[1] + ql[2] + ql[3];
        let mut da = al[0] + al[1] + al[2] + al[3];
        for i in chunks * 4..a.len() {
            dq += a[i] * q[i] as f32;
            da += a[i];
        }
        scale * dq + zero * da
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn axpy_q8_avx2(y: &mut [f32], w: f32, q: &[i8], scale: f32, zero: f32) {
        let ws = w * scale;
        let wz = w * zero;
        let chunks = y.len() / 8;
        unsafe {
            let vws = _mm256_set1_ps(ws);
            let vwz = _mm256_set1_ps(wz);
            for i in 0..chunks {
                let vy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
                let bytes = _mm_loadl_epi64(q.as_ptr().add(i * 8) as *const __m128i);
                let vq = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
                // same per-element op sequence as the scalar kernel:
                // (ws * q) rounded, + wz rounded, then += into y
                let t = _mm256_add_ps(_mm256_mul_ps(vws, vq), vwz);
                _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), _mm256_add_ps(vy, t));
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += ws * q[i] as f32 + wz;
        }
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn dot_f16_f16c(a: &[f32], h: &[u16]) -> f32 {
        // VCVTPH2PS computes the same exact f16 -> f32 bits as the
        // software converter, so hardware conversion stays bitwise.
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..chunks {
                let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
                let bits = _mm_loadl_epi64(h.as_ptr().add(i * 4) as *const __m128i);
                let vh = _mm_cvtph_ps(bits);
                acc = _mm_add_ps(acc, _mm_mul_ps(va, vh));
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in chunks * 4..a.len() {
            s += a[i] * tensor::f16_to_f32(h[i]);
        }
        s
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn axpy_f16_f16c(y: &mut [f32], w: f32, h: &[u16]) {
        let chunks = y.len() / 8;
        unsafe {
            let vw = _mm256_set1_ps(w);
            for i in 0..chunks {
                let vy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
                let bits = _mm_loadu_si128(h.as_ptr().add(i * 8) as *const __m128i);
                let vh = _mm256_cvtph_ps(bits);
                let t = _mm256_add_ps(vy, _mm256_mul_ps(vw, vh));
                _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), t);
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += w * tensor::f16_to_f32(h[i]);
        }
    }

    /// Unpack 4 packed bytes (already in an xmm low dword) into 8
    /// nibble codes in element order, bias-corrected to i8 in [-8, 7]:
    /// low nibble = even element, matching `tensor::quantize_q4`.
    ///
    /// # Safety
    /// Requires SSE2 at runtime (callers carry the Avx2 bundle).
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    unsafe fn unpack_q4(bytes: __m128i) -> __m128i {
        unsafe {
            let low_mask = _mm_set1_epi8(0x0F);
            let lo = _mm_and_si128(bytes, low_mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(bytes), low_mask);
            // interleave -> lo0 hi0 lo1 hi1 ... = element order
            _mm_sub_epi8(_mm_unpacklo_epi8(lo, hi), _mm_set1_epi8(8))
        }
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn dot_i4_sse41(a: &[f32], q: &[u8]) -> f32 {
        // One iteration = 4 bytes = 8 codes = two scalar 4-code chunks,
        // accumulated low-then-high so lane j sees exactly the sequence
        // scalar sq[j] would.
        let pair_chunks = q.len() / 2; // scalar 4-code chunks
        let quads = pair_chunks / 2; // SIMD iterations (4 bytes each)
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = _mm_setzero_ps();
            for i in 0..quads {
                let w = (q.as_ptr().add(i * 4) as *const i32).read_unaligned();
                let codes = unpack_q4(_mm_cvtsi32_si128(w));
                let c0 = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(codes));
                let c1 = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(_mm_srli_si128::<4>(codes)));
                let x0 = _mm_loadu_ps(a.as_ptr().add(i * 8));
                let x1 = _mm_loadu_ps(a.as_ptr().add(i * 8 + 4));
                acc = _mm_add_ps(acc, _mm_mul_ps(x0, c0));
                acc = _mm_add_ps(acc, _mm_mul_ps(x1, c1));
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        // leftover full 4-code chunk (odd chunk count): keep feeding the
        // lanes so the horizontal fold happens at the scalar position
        if pair_chunks % 2 == 1 {
            let i = pair_chunks - 1;
            let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 2..i * 2 + 2]);
            lanes[0] += x[0] * ((c[0] & 0x0F) as i32 - 8) as f32;
            lanes[1] += x[1] * ((c[0] >> 4) as i32 - 8) as f32;
            lanes[2] += x[2] * ((c[1] & 0x0F) as i32 - 8) as f32;
            lanes[3] += x[3] * ((c[1] >> 4) as i32 - 8) as f32;
        }
        let mut dq = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in pair_chunks * 2..q.len() {
            let b = q[i];
            dq += a[2 * i] * ((b & 0x0F) as i32 - 8) as f32;
            dq += a[2 * i + 1] * ((b >> 4) as i32 - 8) as f32;
        }
        dq
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn qk_dot_q4_sse41(a: &[f32], q: &[u8], scale: f32, zero: f32) -> f32 {
        let pair_chunks = q.len() / 2;
        let quads = pair_chunks / 2;
        let mut ql = [0.0f32; 4];
        let mut al = [0.0f32; 4];
        unsafe {
            let mut accq = _mm_setzero_ps();
            let mut acca = _mm_setzero_ps();
            for i in 0..quads {
                let w = (q.as_ptr().add(i * 4) as *const i32).read_unaligned();
                let codes = unpack_q4(_mm_cvtsi32_si128(w));
                let c0 = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(codes));
                let c1 = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(_mm_srli_si128::<4>(codes)));
                let x0 = _mm_loadu_ps(a.as_ptr().add(i * 8));
                let x1 = _mm_loadu_ps(a.as_ptr().add(i * 8 + 4));
                accq = _mm_add_ps(accq, _mm_mul_ps(x0, c0));
                acca = _mm_add_ps(acca, x0);
                accq = _mm_add_ps(accq, _mm_mul_ps(x1, c1));
                acca = _mm_add_ps(acca, x1);
            }
            _mm_storeu_ps(ql.as_mut_ptr(), accq);
            _mm_storeu_ps(al.as_mut_ptr(), acca);
        }
        if pair_chunks % 2 == 1 {
            let i = pair_chunks - 1;
            let (x, c) = (&a[i * 4..i * 4 + 4], &q[i * 2..i * 2 + 2]);
            ql[0] += x[0] * ((c[0] & 0x0F) as i32 - 8) as f32;
            ql[1] += x[1] * ((c[0] >> 4) as i32 - 8) as f32;
            ql[2] += x[2] * ((c[1] & 0x0F) as i32 - 8) as f32;
            ql[3] += x[3] * ((c[1] >> 4) as i32 - 8) as f32;
            al[0] += x[0];
            al[1] += x[1];
            al[2] += x[2];
            al[3] += x[3];
        }
        let mut dq = ql[0] + ql[1] + ql[2] + ql[3];
        let mut da = al[0] + al[1] + al[2] + al[3];
        for i in pair_chunks * 2..q.len() {
            let b = q[i];
            dq += a[2 * i] * ((b & 0x0F) as i32 - 8) as f32;
            dq += a[2 * i + 1] * ((b >> 4) as i32 - 8) as f32;
            da += a[2 * i];
            da += a[2 * i + 1];
        }
        scale * dq + zero * da
    }

    /// # Safety
    /// Requires AVX2 (and the bundled SSE4.1/F16C) at runtime.
    #[target_feature(enable = "avx2,sse4.1,f16c")]
    pub(super) unsafe fn axpy_q4_avx2(y: &mut [f32], w: f32, q: &[u8], scale: f32, zero: f32) {
        let ws = w * scale;
        let wz = w * zero;
        let quads = q.len() / 4; // 4 bytes -> 8 elements per iteration
        unsafe {
            let vws = _mm256_set1_ps(ws);
            let vwz = _mm256_set1_ps(wz);
            for i in 0..quads {
                let w4 = (q.as_ptr().add(i * 4) as *const i32).read_unaligned();
                let codes = unpack_q4(_mm_cvtsi32_si128(w4));
                let vq = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(codes));
                let vy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
                let t = _mm256_add_ps(_mm256_mul_ps(vws, vq), vwz);
                _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), _mm256_add_ps(vy, t));
            }
        }
        for i in quads * 4..q.len() {
            let b = q[i];
            y[2 * i] += ws * ((b & 0x0F) as i32 - 8) as f32 + wz;
            y[2 * i + 1] += ws * ((b >> 4) as i32 - 8) as f32 + wz;
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    // NEON is part of the aarch64 baseline.  Only the f32 plane is
    // vectorized here; the code-dtype kernels dispatch to scalar until
    // they can be validated on real hardware (the aarch64 CI job
    // cross-checks the build but never executes).

    #[inline]
    pub(super) fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let va = vld1q_f32(a.as_ptr().add(i * 4));
                let vb = vld1q_f32(b.as_ptr().add(i * 4));
                // separate mul + add, not vfmaq: scalar rounding order
                acc = vaddq_f32(acc, vmulq_f32(va, vb));
            }
            vst1q_f32(lanes.as_mut_ptr(), acc);
        }
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    #[inline]
    pub(super) fn sum4_neon(a: &[f32]) -> f32 {
        let chunks = a.len() / 4;
        let mut lanes = [0.0f32; 4];
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..chunks {
                acc = vaddq_f32(acc, vld1q_f32(a.as_ptr().add(i * 4)));
            }
            vst1q_f32(lanes.as_mut_ptr(), acc);
        }
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for &x in &a[chunks * 4..] {
            s += x;
        }
        s
    }

    #[inline]
    pub(super) fn axpy_neon(y: &mut [f32], a: f32, x: &[f32]) {
        let chunks = y.len() / 4;
        unsafe {
            let va = vdupq_n_f32(a);
            for i in 0..chunks {
                let vy = vld1q_f32(y.as_ptr().add(i * 4));
                let vx = vld1q_f32(x.as_ptr().add(i * 4));
                vst1q_f32(y.as_mut_ptr().add(i * 4), vaddq_f32(vy, vmulq_f32(va, vx)));
            }
        }
        for i in chunks * 4..y.len() {
            y[i] += a * x[i];
        }
    }

    #[inline]
    pub(super) fn scale_neon(xs: &mut [f32], s: f32) {
        let chunks = xs.len() / 4;
        unsafe {
            let vs = vdupq_n_f32(s);
            for i in 0..chunks {
                let v = vld1q_f32(xs.as_ptr().add(i * 4));
                vst1q_f32(xs.as_mut_ptr().add(i * 4), vmulq_f32(v, vs));
            }
        }
        for x in &mut xs[chunks * 4..] {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn levels() -> Vec<SimdLevel> {
        let v = available_levels();
        assert_eq!(v[0], SimdLevel::Scalar);
        v
    }

    #[test]
    fn detect_is_stable_and_available() {
        let l = detect();
        assert_eq!(l, detect(), "detection must be cached");
        // Whatever detect() picked must be runnable here (unless the env
        // override pinned Scalar, which is always runnable).
        assert!(available_levels().contains(&l) || l == SimdLevel::Scalar);
        if std::env::var("KASCADE_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0") == Ok(true) {
            assert_eq!(l, SimdLevel::Scalar, "env override must pin scalar");
        }
    }

    #[test]
    fn f32_kernels_bitwise_equal_scalar_at_every_level() {
        let mut r = Rng::new(61);
        for level in levels() {
            for _ in 0..30 {
                let n = 1 + r.below(67); // ragged tails included
                let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                assert_eq!(
                    dot(level, &a, &b).to_bits(),
                    tensor::dot(&a, &b).to_bits(),
                    "dot {level:?} n={n}"
                );
                assert_eq!(
                    sum4(level, &a).to_bits(),
                    tensor::sum4(&a).to_bits(),
                    "sum4 {level:?} n={n}"
                );
                let mut y0: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let mut y1 = y0.clone();
                tensor::axpy(&mut y0, 0.7, &b);
                axpy(level, &mut y1, 0.7, &b);
                assert_eq!(y0, y1, "axpy {level:?} n={n}");
                let mut s0 = a.clone();
                let mut s1 = a.clone();
                let m0 = tensor::softmax(&mut s0);
                let m1 = softmax(level, &mut s1);
                assert_eq!(m0.to_bits(), m1.to_bits());
                for (x, y) in s0.iter().zip(&s1) {
                    assert_eq!(x.to_bits(), y.to_bits(), "softmax {level:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn quantized_kernels_bitwise_equal_scalar_at_every_level() {
        let mut r = Rng::new(63);
        for level in levels() {
            for _ in 0..30 {
                let n = 2 * (1 + r.below(33)); // even, ragged vs lane width
                let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let src: Vec<f32> = (0..n).map(|_| r.normal() * 1.5).collect();
                let mut q8 = vec![0i8; n];
                let (s8, z8) = tensor::quantize_q8(&src, &mut q8);
                assert_eq!(
                    dot_i8(level, &a, &q8).to_bits(),
                    tensor::dot_i8(&a, &q8).to_bits(),
                    "dot_i8 {level:?} n={n}"
                );
                assert_eq!(
                    qk_dot_q8(level, &a, &q8, s8, z8).to_bits(),
                    tensor::qk_dot_q8(&a, &q8, s8, z8).to_bits(),
                    "qk_dot_q8 {level:?} n={n}"
                );
                let mut y0: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let mut y1 = y0.clone();
                tensor::axpy_q8(&mut y0, 0.4, &q8, s8, z8);
                axpy_q8(level, &mut y1, 0.4, &q8, s8, z8);
                assert_eq!(y0, y1, "axpy_q8 {level:?} n={n}");

                let h: Vec<u16> = src.iter().map(|&x| tensor::f32_to_f16(x)).collect();
                assert_eq!(
                    dot_f16(level, &a, &h).to_bits(),
                    tensor::dot_f16(&a, &h).to_bits(),
                    "dot_f16 {level:?} n={n}"
                );
                let mut y0: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let mut y1 = y0.clone();
                tensor::axpy_f16(&mut y0, 0.4, &h);
                axpy_f16(level, &mut y1, 0.4, &h);
                assert_eq!(y0, y1, "axpy_f16 {level:?} n={n}");

                let mut q4 = vec![0u8; n / 2];
                let (s4, z4) = tensor::quantize_q4(&src, &mut q4);
                assert_eq!(
                    dot_i4(level, &a, &q4).to_bits(),
                    tensor::dot_i4(&a, &q4).to_bits(),
                    "dot_i4 {level:?} n={n}"
                );
                assert_eq!(
                    qk_dot_q4(level, &a, &q4, s4, z4).to_bits(),
                    tensor::qk_dot_q4(&a, &q4, s4, z4).to_bits(),
                    "qk_dot_q4 {level:?} n={n}"
                );
                let mut y0: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let mut y1 = y0.clone();
                tensor::axpy_q4(&mut y0, 0.4, &q4, s4, z4);
                axpy_q4(level, &mut y1, 0.4, &q4, s4, z4);
                assert_eq!(y0, y1, "axpy_q4 {level:?} n={n}");
            }
        }
    }

    #[test]
    fn topk_into_matches_tensor_exactly() {
        let mut r = Rng::new(65);
        let mut pairs = Vec::new();
        let (mut out0, mut out1) = (Vec::new(), Vec::new());
        for level in levels() {
            for _ in 0..20 {
                let n = 5 + r.below(400);
                let k = r.below(n + 1);
                let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                out0.clear();
                out1.clear();
                tensor::topk_unordered_into(&vals, k, &mut pairs, &mut out0);
                topk_into(level, &vals, k, &mut pairs, &mut out1);
                assert_eq!(out0, out1, "{level:?} n={n} k={k}");
            }
        }
    }
}
