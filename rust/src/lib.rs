//! # Kascade — practical sparse attention for long-context LLM inference
//!
//! A Rust + JAX + Pallas reproduction of *"Kascade: A Practical Sparse
//! Attention Method for Long-Context LLM Inference"* (Deshmukh et al.,
//! 2025), built as a three-layer stack:
//!
//! * **L3 (this crate)** — a serving coordinator (router, continuous
//!   batcher, paged KV cache, prefill/decode scheduler) plus the paper's
//!   offline algorithms: cross-layer similarity (Eq. 3), dynamic-programming
//!   anchor-layer selection (Algorithm 1), head remapping (Sec. 3.5) and
//!   the serve-time Top-k index state.
//! * **L2 (python/compile/model.py)** — a GQA transformer in JAX, lowered
//!   once to HLO-text artifacts executed here via PJRT ([`runtime`]).
//! * **L1 (python/compile/kernels/)** — Pallas kernels: dense flash
//!   attention, the multi-pass anchor pipeline, and gather-based reuse
//!   attention.
//!
//! The crate additionally contains a **native CPU attention engine**
//! ([`attention`], [`model`]) — the simulator substrate used to run the
//! paper's accuracy experiments (Figs. 1-7, Tables 1-2) at long contexts,
//! and **SynthLM** ([`model`]), a synthetic GQA transformer with wired
//! retrieval circuits that makes task accuracy *really* depend on
//! attention fidelity (DESIGN.md §2).

pub mod attention;
pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod jsonutil;
pub mod kascade;
pub mod model;
pub mod runtime;
pub mod proptest_lite;
pub mod server;
pub mod sparse;
pub mod stats;
pub mod tensor;
pub mod workload;
