//! # Kascade — practical sparse attention for long-context LLM inference
//!
//! A Rust + JAX + Pallas reproduction of *"Kascade: A Practical Sparse
//! Attention Method for Long-Context LLM Inference"* (Deshmukh et al.,
//! 2025), built as a three-layer stack:
//!
//! * **L3 (this crate)** — a serving coordinator (router, continuous
//!   batcher, paged KV cache with refcounted copy-on-write block sharing,
//!   automatic prefix caching, prefill/decode scheduler) plus the paper's
//!   offline algorithms: cross-layer similarity (Eq. 3), dynamic-programming
//!   anchor-layer selection (Algorithm 1), head remapping (Sec. 3.5) and
//!   the serve-time Top-k index state.
//! * **L2 (python/compile/model.py)** — a GQA transformer in JAX, lowered
//!   once to HLO-text artifacts executed here via PJRT ([`runtime`]).
//! * **L1 (python/compile/kernels/)** — Pallas kernels: dense flash
//!   attention, the multi-pass anchor pipeline, and gather-based reuse
//!   attention.
//!
//! The crate additionally contains a **native CPU attention engine**
//! ([`attention`], [`model`]) — the simulator substrate used to run the
//! paper's accuracy experiments (Figs. 1-7, Tables 1-2) at long contexts,
//! and **SynthLM** ([`model`]), a synthetic GQA transformer with wired
//! retrieval circuits that makes task accuracy *really* depend on
//! attention fidelity (DESIGN.md §2).
//!
//! ## Prefix caching (docs/serving.md)
//!
//! The coordinator implements vLLM-style automatic prefix caching for
//! the RAG / agentic workloads Kascade targets: prompts are indexed by
//! hash-of-token-block chains ([`coordinator::prefix_cache`]), full KV
//! blocks are shared across sequences through refcounts with
//! copy-on-write on divergence ([`coordinator::blocks`]), and admission
//! starts a matching sequence at its first uncached token, resuming
//! backend state from an engine-held snapshot
//! ([`coordinator::SeqBackend::fork_prefix`]).  Block lifecycle:
//! allocated -> shared -> cached -> evicted; see `docs/serving.md` for
//! the full state machine and the prefix-cache/Kascade-index
//! interaction (KV blocks are shared, per-sequence Top-k index state is
//! not).

pub mod analyze;
pub mod attention;
pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod gateway;
pub mod jsonutil;
pub mod kascade;
pub mod model;
pub mod pool;
pub mod runtime;
pub mod proptest_lite;
pub mod server;
pub mod simd;
pub mod sparse;
pub mod stats;
pub mod tensor;
pub mod tilestore;
pub mod workload;
