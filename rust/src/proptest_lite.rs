//! Lightweight property-based testing harness (proptest is unavailable in
//! this offline environment).  Drives a property over many generated cases
//! from the deterministic [`crate::tensor::Rng`]; on failure, reports the
//! seed so the case can be replayed.

use crate::tensor::Rng;

/// Run `prop` over `cases` randomized cases.  Panics with the offending
/// case seed on the first failure.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0xBEEF_0000 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("uniform in range", 50, |rng| {
            let u = rng.uniform();
            prop_assert!((0.0..1.0).contains(&u), "u = {u}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
