//! Native CPU GQA attention engine — the simulator substrate for the
//! paper's accuracy and kernel-shape experiments.
//!
//! Mirrors the semantics of the Pallas kernels (python/compile/kernels/):
//! dense decode/prefill, post-softmax pooled scores (GQA pooling in
//! decode, Q-tile pooling in prefill), sparse attention over explicit
//! per-KV-head index sets with causal clamping, and the multi-pass anchor
//! pipeline cost structure.  A [`CostTracker`] accounts key/value reads and
//! score FLOPs so experiments can report work ratios alongside wall-clock.

use crate::config::KvDtype;
use crate::tensor::{
    axpy_q8, dequantize_q8, dot, qk_dot_q8, quantize_q8, softmax, topk_indices_unordered,
};

/// Per-layer KV cache: contiguous `[n_kv, cap, d]` storage plus per-page
/// min/max key summaries (used by the Quest baseline).
///
/// Two storage modes ([`KvDtype`]):
///
/// * **F32** — plain f32 buffers, the exact baseline.
/// * **Int8** — completed quantization tiles (one tile = `page_size`
///   positions, aligned with the paged-KV block size) are stored as int8
///   with a per-tile, per-head affine `(scale, zero)` pair for K and for
///   V; the current partially-filled tail tile lives in a small f32
///   staging buffer (`[n_kv, page_size, d]`) until it completes, then is
///   quantized once with its final min/max and never touched again —
///   which is what lets copy-on-write forks share quantized blocks
///   byte-for-byte without re-quantizing.
///
/// Kernels never read raw storage directly: [`KvCache::dot_key`] scores
/// fused over int8 rows (no dequantized materialization) and
/// [`KvCache::add_val`] dequantizes value rows on attend.
#[derive(Clone)]
pub struct KvCache {
    pub n_kv: usize,
    pub d: usize,
    pub cap: usize,
    pub len: usize,
    dtype: KvDtype,
    /// F32 mode: full `[n_kv, cap, d]` K/V storage.  Int8 mode: the f32
    /// staging tail, `[n_kv, page_size, d]` (current partial tile only).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Int8 mode: quantized completed tiles, `[n_kv, cap, d]`.
    kq: Vec<i8>,
    vq: Vec<i8>,
    /// Int8 mode: per `(head, tile)` affine params, `[n_kv, n_tiles]`.
    kscale: Vec<f32>,
    kzero: Vec<f32>,
    vscale: Vec<f32>,
    vzero: Vec<f32>,
    /// page summaries: for each kv head and page, elementwise min and max
    /// of the keys in the page: `[n_kv, n_pages, 2, d]`.
    page_size: usize,
    pages: Vec<f32>,
}

impl KvCache {
    pub fn new(n_kv: usize, d: usize, cap: usize) -> Self {
        Self::with_page_size(n_kv, d, cap, 16)
    }

    pub fn with_page_size(n_kv: usize, d: usize, cap: usize, page_size: usize) -> Self {
        Self::with_opts(n_kv, d, cap, page_size, KvDtype::F32)
    }

    pub fn with_opts(n_kv: usize, d: usize, cap: usize, page_size: usize, dtype: KvDtype) -> Self {
        let n_pages = cap.div_ceil(page_size);
        let (f32_len, q_len, s_len) = match dtype {
            KvDtype::F32 => (n_kv * cap * d, 0, 0),
            KvDtype::Int8 => (n_kv * page_size * d, n_kv * cap * d, n_kv * n_pages),
        };
        Self {
            n_kv,
            d,
            cap,
            len: 0,
            dtype,
            k: vec![0.0; f32_len],
            v: vec![0.0; f32_len],
            kq: vec![0; q_len],
            vq: vec![0; q_len],
            kscale: vec![0.0; s_len],
            kzero: vec![0.0; s_len],
            vscale: vec![0.0; s_len],
            vzero: vec![0.0; s_len],
            page_size,
            pages: vec![0.0; n_kv * n_pages * 2 * d],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_pages(&self) -> usize {
        self.len.div_ceil(self.page_size)
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    #[inline]
    pub fn is_quantized(&self) -> bool {
        self.dtype == KvDtype::Int8
    }

    /// First position of the f32 staging tail (Int8 mode): positions at
    /// or beyond this sit in the not-yet-quantized partial tile.
    #[inline]
    fn staged_from(&self) -> usize {
        (self.len / self.page_size) * self.page_size
    }

    /// KV bytes resident for the `len` stored positions (storage the
    /// tokens actually occupy; excludes unused capacity).  Int8 counts
    /// the quantized tiles, the per-tile scale/zero params, and the f32
    /// staging tail.
    pub fn kv_bytes(&self) -> usize {
        let rows = self.n_kv * self.d * 2; // K + V elements per position
        match self.dtype {
            KvDtype::F32 => self.len * rows * 4,
            KvDtype::Int8 => {
                let full = self.staged_from();
                let staged = self.len - full;
                let tiles = full / self.page_size;
                full * rows + staged * rows * 4 + tiles * self.n_kv * 4 * 4
            }
        }
    }

    /// Append one position: `k_new`/`v_new` are `[n_kv * d]` (head-major).
    pub fn push(&mut self, k_new: &[f32], v_new: &[f32]) {
        assert!(self.len < self.cap, "KV cache overflow (cap {})", self.cap);
        debug_assert_eq!(k_new.len(), self.n_kv * self.d);
        let pos = self.len;
        let page = pos / self.page_size;
        let r = pos % self.page_size;
        let fresh_page = r == 0;
        for h in 0..self.n_kv {
            let dst = match self.dtype {
                KvDtype::F32 => (h * self.cap + pos) * self.d,
                KvDtype::Int8 => (h * self.page_size + r) * self.d,
            };
            self.k[dst..dst + self.d].copy_from_slice(&k_new[h * self.d..(h + 1) * self.d]);
            self.v[dst..dst + self.d].copy_from_slice(&v_new[h * self.d..(h + 1) * self.d]);
            // update page min/max
            let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
            let (mins, rest) = self.pages[pb..pb + 2 * self.d].split_at_mut(self.d);
            let maxs = rest;
            let krow = &k_new[h * self.d..(h + 1) * self.d];
            if fresh_page {
                mins.copy_from_slice(krow);
                maxs.copy_from_slice(krow);
            } else {
                for i in 0..self.d {
                    mins[i] = mins[i].min(krow[i]);
                    maxs[i] = maxs[i].max(krow[i]);
                }
            }
        }
        self.len += 1;
        if self.dtype == KvDtype::Int8 && r == self.page_size - 1 {
            self.quantize_tile(page);
        }
    }

    /// Quantize the (full) staging tile into the int8 store (Int8 mode).
    fn quantize_tile(&mut self, tile: usize) {
        let td = self.page_size * self.d;
        let nt = self.cap.div_ceil(self.page_size);
        for h in 0..self.n_kv {
            let src = h * td;
            let dst = (h * self.cap + tile * self.page_size) * self.d;
            let (ks, kz) = quantize_q8(&self.k[src..src + td], &mut self.kq[dst..dst + td]);
            let (vs, vz) = quantize_q8(&self.v[src..src + td], &mut self.vq[dst..dst + td]);
            self.kscale[h * nt + tile] = ks;
            self.kzero[h * nt + tile] = kz;
            self.vscale[h * nt + tile] = vs;
            self.vzero[h * nt + tile] = vz;
        }
    }

    /// Raw f32 key row.  Int8 mode: only valid for staged (tail)
    /// positions — completed tiles have no f32 representation.
    #[inline]
    pub fn key(&self, h: usize, pos: usize) -> &[f32] {
        let o = match self.dtype {
            KvDtype::F32 => (h * self.cap + pos) * self.d,
            KvDtype::Int8 => {
                assert!(pos >= self.staged_from(), "f32 key read of quantized position {pos}");
                (h * self.page_size + pos % self.page_size) * self.d
            }
        };
        &self.k[o..o + self.d]
    }

    /// Raw f32 value row (same staging restriction as [`KvCache::key`]).
    #[inline]
    pub fn val(&self, h: usize, pos: usize) -> &[f32] {
        let o = match self.dtype {
            KvDtype::F32 => (h * self.cap + pos) * self.d,
            KvDtype::Int8 => {
                assert!(pos >= self.staged_from(), "f32 val read of quantized position {pos}");
                (h * self.page_size + pos % self.page_size) * self.d
            }
        };
        &self.v[o..o + self.d]
    }

    /// `dot(q, key(h, pos))` in whatever precision the row is stored:
    /// f32 rows use the exact [`dot`]; quantized rows the fused
    /// [`qk_dot_q8`] (no dequantized materialization).
    #[inline]
    pub fn dot_key(&self, h: usize, pos: usize, q: &[f32]) -> f32 {
        match self.dtype {
            KvDtype::F32 => dot(q, self.key(h, pos)),
            KvDtype::Int8 => {
                if pos >= self.staged_from() {
                    dot(q, self.key(h, pos))
                } else {
                    let tile = pos / self.page_size;
                    let nt = self.cap.div_ceil(self.page_size);
                    let o = (h * self.cap + pos) * self.d;
                    qk_dot_q8(
                        q,
                        &self.kq[o..o + self.d],
                        self.kscale[h * nt + tile],
                        self.kzero[h * nt + tile],
                    )
                }
            }
        }
    }

    /// `out += w * val(h, pos)` — f32 rows via [`crate::tensor::axpy`],
    /// quantized rows via the fused dequantize-on-attend [`axpy_q8`].
    #[inline]
    pub fn add_val(&self, h: usize, pos: usize, w: f32, out: &mut [f32]) {
        match self.dtype {
            KvDtype::F32 => crate::tensor::axpy(out, w, self.val(h, pos)),
            KvDtype::Int8 => {
                if pos >= self.staged_from() {
                    crate::tensor::axpy(out, w, self.val(h, pos));
                } else {
                    let tile = pos / self.page_size;
                    let nt = self.cap.div_ceil(self.page_size);
                    let o = (h * self.cap + pos) * self.d;
                    axpy_q8(
                        out,
                        w,
                        &self.vq[o..o + self.d],
                        self.vscale[h * nt + tile],
                        self.vzero[h * nt + tile],
                    );
                }
            }
        }
    }

    /// The stored int8 key row and its tile `(scale, zero)` — `None` for
    /// f32 caches and staged positions.  Diagnostics/tests only (e.g.
    /// asserting CoW forks share quantized tiles byte-for-byte).
    pub fn quantized_key_row(&self, h: usize, pos: usize) -> Option<(&[i8], f32, f32)> {
        if self.dtype != KvDtype::Int8 || pos >= self.staged_from() {
            return None;
        }
        let tile = pos / self.page_size;
        let nt = self.cap.div_ceil(self.page_size);
        let o = (h * self.cap + pos) * self.d;
        Some((&self.kq[o..o + self.d], self.kscale[h * nt + tile], self.kzero[h * nt + tile]))
    }

    /// (min, max) key summary of `page` for head `h`.
    pub fn page_summary(&self, h: usize, page: usize) -> (&[f32], &[f32]) {
        let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
        (&self.pages[pb..pb + self.d], &self.pages[pb + self.d..pb + 2 * self.d])
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Truncate to the first `n` positions (prefix-cache snapshot forks).
    /// The (now partial) last page's min/max summary is rebuilt from the
    /// stored keys so Quest-style page bounds stay exact after
    /// truncation.  Int8 mode: a boundary inside a completed tile
    /// dequantizes that tile's surviving rows back into the staging tail
    /// (they re-quantize when the tile refills); tile-aligned boundaries
    /// — the common case, since prefix-cache snapshots are block-aligned
    /// and blocks equal tiles — keep every quantized tile byte-for-byte.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.len, "truncate {n} beyond len {}", self.len);
        let old_len = self.len;
        self.len = n;
        if n == 0 {
            return;
        }
        let ps = self.page_size;
        let d = self.d;
        let tail = n % ps;
        if self.dtype == KvDtype::Int8 && tail != 0 {
            let tile = n / ps;
            if old_len / ps > tile {
                // the tail tile had completed: restore its surviving rows
                // into staging from the quantized store
                let nt = self.cap.div_ceil(ps);
                for h in 0..self.n_kv {
                    let (ks, kz) = (self.kscale[h * nt + tile], self.kzero[h * nt + tile]);
                    let (vs, vz) = (self.vscale[h * nt + tile], self.vzero[h * nt + tile]);
                    for r in 0..tail {
                        let src = (h * self.cap + tile * ps + r) * d;
                        let dst = (h * ps + r) * d;
                        dequantize_q8(&self.kq[src..src + d], ks, kz, &mut self.k[dst..dst + d]);
                        dequantize_q8(&self.vq[src..src + d], vs, vz, &mut self.v[dst..dst + d]);
                    }
                }
            }
            // else: the tile was already partial; rows [tile*ps, n) are a
            // prefix of what staging holds — nothing to restore
        }
        let page = (n - 1) / ps;
        if self.dtype == KvDtype::Int8 && tail == 0 {
            // tile-aligned boundary: the last page was complete before
            // truncation too, so its stored summary is already exact (and
            // its raw f32 rows no longer exist to rebuild from)
            return;
        }
        let p0 = page * ps;
        for h in 0..self.n_kv {
            let mut mins = vec![f32::INFINITY; d];
            let mut maxs = vec![f32::NEG_INFINITY; d];
            for pos in p0..n {
                let row = self.key(h, pos);
                for i in 0..d {
                    mins[i] = mins[i].min(row[i]);
                    maxs[i] = maxs[i].max(row[i]);
                }
            }
            let pb = ((h * self.cap.div_ceil(ps)) + page) * 2 * d;
            self.pages[pb..pb + d].copy_from_slice(&mins);
            self.pages[pb + d..pb + 2 * d].copy_from_slice(&maxs);
        }
    }
}

/// Work accounting for the cost-model side of Table 3 / Fig 8.
#[derive(Debug, Default, Clone, Copy)]
pub struct CostTracker {
    /// K rows read for score computation (dense or estimation passes).
    pub score_key_reads: u64,
    /// K/V rows read for the weighted-sum (output) computation.
    pub attend_kv_reads: u64,
    /// Entries pushed through top-k selection.
    pub topk_items: u64,
    /// Quantized KV rows read through the dequantizing attend path
    /// (value reads of int8 tiles).  Scoring over quantized keys is
    /// fused ([`crate::tensor::qk_dot_q8`]) and never counts here — the
    /// gap between `attend_kv_reads` and `dequant_rows` is exactly the
    /// work the Top-k selection saved from touching full precision.
    pub dequant_rows: u64,
}

impl CostTracker {
    pub fn merge(&mut self, o: &CostTracker) {
        self.score_key_reads += o.score_key_reads;
        self.attend_kv_reads += o.attend_kv_reads;
        self.topk_items += o.topk_items;
        self.dequant_rows += o.dequant_rows;
    }
}

/// Scale for all scores: 1/sqrt(d).
#[inline]
fn scale(d: usize) -> f32 {
    1.0 / (d as f32).sqrt()
}

// ---------------------------------------------------------------------------
// decode attention
// ---------------------------------------------------------------------------

/// Dense GQA decode attention.  `q` is `[n_q * d]` head-major, `out` too.
/// Attends to `cache.len` keys.
pub fn decode_dense(q: &[f32], cache: &KvCache, g: usize, out: &mut [f32], cost: &mut CostTracker) {
    let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
    let sc = scale(d);
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = cache.dot_key(h, p, qrow) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for p in 0..len {
                let w = s[p];
                if w > 1e-9 {
                    cache.add_val(h, p, w, orow);
                }
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    cost.attend_kv_reads += (n_kv * g * len) as u64;
    if cache.is_quantized() {
        cost.dequant_rows += (n_kv * g * len) as u64;
    }
}

/// Per-query-head post-softmax distributions for one decode query:
/// `[n_q][len]`.
pub fn decode_head_scores(q: &[f32], cache: &KvCache, g: usize, cost: &mut CostTracker) -> Vec<Vec<f32>> {
    let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
    let sc = scale(d);
    let mut all = Vec::with_capacity(n_kv * g);
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            let mut s = vec![0.0f32; len];
            for p in 0..len {
                s[p] = cache.dot_key(h, p, qrow) * sc;
            }
            softmax(&mut s);
            all.push(s);
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    all
}

/// GQA post-softmax pooling (paper Sec. 3.4, decode): mean of the group's
/// distributions, per KV head: `[n_kv][len]`.
pub fn decode_pooled_scores(q: &[f32], cache: &KvCache, g: usize, cost: &mut CostTracker) -> Vec<Vec<f32>> {
    let per_head = decode_head_scores(q, cache, g, cost);
    pool_groups(&per_head, g)
}

/// Pooled scores clamped to the first `upto` cache entries (used for
/// calibration probes at prefill positions).
pub fn decode_pooled_scores_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    cost: &mut CostTracker,
) -> Vec<Vec<f32>> {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let len = upto.min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / g as f32;
    let mut pooled = vec![vec![0.0f32; len]; n_kv];
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = cache.dot_key(h, p, qrow) * sc;
            }
            softmax(&mut s);
            for p in 0..len {
                pooled[h][p] += s[p] * inv;
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    pooled
}

/// Mean-pool groups of `g` consecutive distributions.
pub fn pool_groups(per_head: &[Vec<f32>], g: usize) -> Vec<Vec<f32>> {
    let n_kv = per_head.len() / g;
    let len = per_head[0].len();
    let inv = 1.0 / g as f32;
    (0..n_kv)
        .map(|h| {
            let mut p = vec![0.0f32; len];
            for qi in 0..g {
                for (pi, &x) in p.iter_mut().zip(per_head[h * g + qi].iter()) {
                    *pi += x * inv;
                }
            }
            p
        })
        .collect()
}

/// Sparse decode attention over per-KV-head index sets.
pub fn decode_sparse(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    idx: &[Vec<u32>],
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let sc = scale(d);
    let mut total = 0u64;
    for (h, hidx) in idx.iter().enumerate() {
        let mut s = vec![0.0f32; hidx.len()];
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for (j, &p) in hidx.iter().enumerate() {
                s[j] = cache.dot_key(h, p as usize, qrow) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for (j, &p) in hidx.iter().enumerate() {
                if s[j] > 1e-9 {
                    cache.add_val(h, p as usize, s[j], orow);
                }
            }
        }
        total += (g * hidx.len()) as u64;
    }
    cost.score_key_reads += total;
    cost.attend_kv_reads += total;
    if cache.is_quantized() {
        cost.dequant_rows += total;
    }
}

// ---------------------------------------------------------------------------
// prefill attention (tile-based)
// ---------------------------------------------------------------------------

/// Dense causal prefill attention for a tile of queries.
///
/// `qs` is `[tile, n_q * d]`; query row `r` sits at absolute position
/// `start + r` and attends to keys `[0, start + r]` (the cache must already
/// contain the tile's own keys).  `out` is `[tile, n_q * d]`.
pub fn prefill_dense_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    for r in 0..tile {
        decode_dense_upto(
            &qs[r * n_q * d..(r + 1) * n_q * d],
            start + r + 1,
            cache,
            g,
            &mut out[r * n_q * d..(r + 1) * n_q * d],
            cost,
        );
    }
}

/// Dense decode attention clamped to the first `upto` cache entries.
pub fn decode_dense_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let len = upto.min(cache.len);
    let sc = scale(d);
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = cache.dot_key(h, p, qrow) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for p in 0..len {
                if s[p] > 1e-9 {
                    cache.add_val(h, p, s[p], orow);
                }
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    cost.attend_kv_reads += (n_kv * g * len) as u64;
    if cache.is_quantized() {
        cost.dequant_rows += (n_kv * g * len) as u64;
    }
}

/// Tile-level post-softmax pooled scores for prefill (anchor passes 1+2):
/// the mean over (GQA group x tile rows) of each query's causal
/// post-softmax distribution, per KV head: `[n_kv][kv_len]` where
/// `kv_len = start + tile`.
pub fn prefill_pooled_scores(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    cost: &mut CostTracker,
) -> Vec<Vec<f32>> {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let n_q = n_kv * g;
    let tile = qs.len() / (n_q * d);
    let kv_len = (start + tile).min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / (tile * g) as f32;
    // causal triangular work: row r reads min(start + r + 1, kv_len) keys
    // per (head, group) query — NOT tile * kv_len (Fig. 8 / Table 3 cost
    // ratios were overcounting the anchor pass before this was fixed)
    let row_reads: u64 = (0..tile).map(|r| (start + r + 1).min(kv_len) as u64).sum();
    let mut pooled = vec![vec![0.0f32; kv_len]; n_kv];
    let mut s = vec![0.0f32; kv_len];
    for h in 0..n_kv {
        for r in 0..tile {
            let upto = (start + r + 1).min(kv_len);
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                for p in 0..upto {
                    s[p] = cache.dot_key(h, p, qrow) * sc;
                }
                softmax(&mut s[..upto]);
                for p in 0..upto {
                    pooled[h][p] += s[p] * inv;
                }
            }
        }
        cost.score_key_reads += g as u64 * row_reads;
    }
    pooled
}

/// Sparse prefill attention for a tile with tile-shared indices and
/// per-query causal clamping (paper Sec. 3.4 / 4.1 rolling Top-k).
pub fn prefill_sparse_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    idx: &[Vec<u32>],
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    let sc = scale(d);
    for r in 0..tile {
        let qpos = start + r;
        for (h, hidx) in idx.iter().enumerate() {
            let mut s = Vec::with_capacity(hidx.len() + r + 1);
            let mut kept: Vec<u32> = Vec::with_capacity(hidx.len() + r + 1);
            // which of the tile's own (causally visible) positions the
            // index set already covers: offset j <=> position start + j
            let mut own = vec![false; r + 1];
            for &p in hidx {
                if (p as usize) <= qpos {
                    kept.push(p);
                    if (p as usize) >= start {
                        own[p as usize - start] = true;
                    }
                }
            }
            // rolling-Top-k guarantee (paper Sec. 4.1): a tile's own
            // positions are always visible to its queries, even when the
            // anchor's indices all land in this query's causal future
            for (j, seen) in own.iter().enumerate() {
                if !seen {
                    kept.push((start + j) as u32);
                }
            }
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                s.clear();
                for &p in &kept {
                    s.push(cache.dot_key(h, p as usize, qrow) * sc);
                }
                softmax(&mut s);
                let orow = &mut out[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                orow.fill(0.0);
                for (j, &p) in kept.iter().enumerate() {
                    if s[j] > 1e-9 {
                        cache.add_val(h, p as usize, s[j], orow);
                    }
                }
            }
            cost.score_key_reads += (g * kept.len()) as u64;
            cost.attend_kv_reads += (g * kept.len()) as u64;
            if cache.is_quantized() {
                cost.dequant_rows += (g * kept.len()) as u64;
            }
        }
    }
}

/// Top-k over pooled scores (anchor pass 3).  Uses the O(n) unordered
/// quickselect — attention is order-invariant over the index set.
pub fn select_topk(pooled: &[Vec<f32>], k: usize, cost: &mut CostTracker) -> Vec<Vec<u32>> {
    pooled
        .iter()
        .map(|p| {
            cost.topk_items += p.len() as u64;
            topk_indices_unordered(p, k.min(p.len()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n_kv: usize, g: usize, d: usize, len: usize, seed: u64) -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(seed);
        let n_q = n_kv * g;
        let mut q = vec![0.0; n_q * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len + 8);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        (q, cache)
    }

    #[test]
    fn dense_decode_is_convex_combination() {
        let (q, cache) = setup(2, 2, 16, 64, 1);
        let mut out = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        decode_dense(&q, &cache, 2, &mut out, &mut c);
        // bounded by value hull per kv head
        for h in 0..2 {
            let mut vmax = f32::NEG_INFINITY;
            let mut vmin = f32::INFINITY;
            for p in 0..64 {
                for &x in cache.val(h, p) {
                    vmax = vmax.max(x);
                    vmin = vmin.min(x);
                }
            }
            for qi in 0..2 {
                for &x in &out[(h * 2 + qi) * 16..(h * 2 + qi + 1) * 16] {
                    assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4);
                }
            }
        }
        assert_eq!(c.score_key_reads, 4 * 64);
    }

    #[test]
    fn sparse_with_all_indices_equals_dense() {
        let (q, cache) = setup(2, 2, 16, 64, 2);
        let mut dense = vec![0.0; 4 * 16];
        let mut sparse = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        decode_dense(&q, &cache, 2, &mut dense, &mut c);
        let idx: Vec<Vec<u32>> = vec![(0..64).collect(), (0..64).collect()];
        decode_sparse(&q, &cache, 2, &idx, &mut sparse, &mut c);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pooled_scores_are_distributions() {
        let (q, cache) = setup(2, 2, 16, 64, 3);
        let mut c = CostTracker::default();
        let pooled = decode_pooled_scores(&q, &cache, 2, &mut c);
        assert_eq!(pooled.len(), 2);
        for p in &pooled {
            assert_eq!(p.len(), 64);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn topk_sparse_approximates_dense_when_peaked() {
        // make one key align strongly with the query
        let mut r = Rng::new(4);
        let (n_kv, g, d, len) = (2, 2, 16, 128);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len);
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.2);
            r.fill_normal(&mut v, 1.0);
            if p == 77 {
                // strong alignment for every (kv, q) pair
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let pooled = decode_pooled_scores(&q, &cache, g, &mut c);
        let idx = select_topk(&pooled, 16, &mut c);
        assert!(idx.iter().all(|hi| hi.contains(&77)));
        let mut dense = vec![0.0; n_kv * g * d];
        let mut sparse = vec![0.0; n_kv * g * d];
        decode_dense(&q, &cache, g, &mut dense, &mut c);
        decode_sparse(&q, &cache, g, &idx, &mut sparse, &mut c);
        let cos = crate::tensor::cosine_sim(&dense, &sparse);
        assert!(cos > 0.9, "cos {cos}");
    }

    #[test]
    fn prefill_dense_tile_matches_per_token_decode() {
        let mut r = Rng::new(5);
        let (n_kv, g, d, len) = (2, 2, 8, 32);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, len);
        let mut qs = vec![0.0; len * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let mut tile_out = vec![0.0; len * n_q * d];
        prefill_dense_tile(&qs, 0, &cache, g, &mut tile_out, &mut c);
        for t in 0..len {
            let mut want = vec![0.0; n_q * d];
            decode_dense_upto(&qs[t * n_q * d..(t + 1) * n_q * d], t + 1, &cache, g, &mut want, &mut c);
            for (a, b) in tile_out[t * n_q * d..(t + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_pooled_rows_sum_to_one() {
        let mut r = Rng::new(6);
        let (n_kv, g, d, tile) = (2, 2, 8, 16);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut c = CostTracker::default();
        let pooled = prefill_pooled_scores(&qs, 32, &cache, g, &mut c);
        for p in &pooled {
            assert_eq!(p.len(), 48);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        }
    }

    #[test]
    fn prefill_sparse_clamps_future_indices() {
        let mut r = Rng::new(7);
        let (n_kv, g, d, tile) = (1, 2, 8, 8);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..8 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // indices include every position; query 0 may only use position 0
        let idx = vec![(0..8u32).collect::<Vec<_>>()];
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        prefill_sparse_tile(&qs, 0, &cache, g, &idx, &mut out, &mut c);
        for hq in 0..n_q {
            for i in 0..d {
                assert!((out[hq * d + i] - cache.val(0, 0)[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_sparse_always_sees_tile_own_positions() {
        // all anchor indices land in the tile's future: every query must
        // still see the tile's own causally-visible range (Sec. 4.1), not
        // collapse to self-only attention
        let mut r = Rng::new(12);
        let (n_kv, g, d, tile, start) = (1usize, 2usize, 8usize, 8usize, 8usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..16 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // anchor indices all at the end of the tile (future for early rows)
        let idx = vec![vec![12u32, 13, 14, 15]];
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        prefill_sparse_tile(&qs, start, &cache, g, &idx, &mut out, &mut c);
        for row in 0..tile {
            let qpos = start + row;
            // expected: attention over the union {idx <= qpos} u {start..=qpos},
            // which here is exactly the tile's own visible range
            let expect_idx: Vec<Vec<u32>> = vec![(start as u32..=qpos as u32).collect()];
            let mut want = vec![0.0; n_q * d];
            decode_sparse(
                &qs[row * n_q * d..(row + 1) * n_q * d],
                &cache,
                g,
                &expect_idx,
                &mut want,
                &mut CostTracker::default(),
            );
            for (a, b) in out[row * n_q * d..(row + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "row {row}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn prefill_pooled_cost_matches_dense_tile_pass() {
        // the anchor estimation pass reads exactly the causal triangle of
        // keys — its accounted cost must equal the dense tile pass's
        let mut r = Rng::new(13);
        let (n_kv, g, d, tile, start) = (2usize, 2usize, 8usize, 16usize, 32usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut c_pool = CostTracker::default();
        let _ = prefill_pooled_scores(&qs, start, &cache, g, &mut c_pool);
        let mut c_dense = CostTracker::default();
        let mut out = vec![0.0; tile * n_q * d];
        prefill_dense_tile(&qs, start, &cache, g, &mut out, &mut c_dense);
        assert_eq!(c_pool.score_key_reads, c_dense.score_key_reads);
        // triangular sum, explicitly: sum_r min(start + r + 1, kv_len)
        let want: u64 = (0..tile).map(|r| (start + r + 1).min(48) as u64).sum();
        assert_eq!(c_pool.score_key_reads, (n_kv * g) as u64 * want);
    }

    #[test]
    fn page_summaries_bound_keys() {
        let (_, cache) = setup(2, 2, 16, 70, 8);
        for h in 0..2 {
            for page in 0..cache.n_pages() {
                let (mins, maxs) = cache.page_summary(h, page);
                let lo = page * cache.page_size();
                let hi = ((page + 1) * cache.page_size()).min(cache.len);
                for p in lo..hi {
                    for (i, &x) in cache.key(h, p).iter().enumerate() {
                        assert!(x >= mins[i] - 1e-6 && x <= maxs[i] + 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn cache_overflow_panics() {
        let mut cache = KvCache::new(1, 4, 2);
        let k = vec![0.0; 4];
        for _ in 0..3 {
            cache.push(&k, &k);
        }
    }

    /// Build an f32 cache and an int8 cache holding identical pushes.
    fn paired_caches(n_kv: usize, d: usize, len: usize, seed: u64) -> (KvCache, KvCache) {
        let mut r = Rng::new(seed);
        let mut cf = KvCache::new(n_kv, d, len + 8);
        let mut cq = KvCache::with_opts(n_kv, d, len + 8, 16, crate::config::KvDtype::Int8);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        (cf, cq)
    }

    #[test]
    fn int8_dense_decode_close_to_f32() {
        let mut r = Rng::new(41);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, cq) = paired_caches(n_kv, d, len, 42);
        let mut of = vec![0.0; n_kv * g * d];
        let mut oq = vec![0.0; n_kv * g * d];
        let mut c = CostTracker::default();
        decode_dense(&q, &cf, g, &mut of, &mut c);
        let mut c8 = CostTracker::default();
        decode_dense(&q, &cq, g, &mut oq, &mut c8);
        let cos = crate::tensor::cosine_sim(&of, &oq);
        assert!(cos > 0.999, "cos {cos}");
        assert!(c8.dequant_rows > 0, "dense fallback must dequantize");
        assert_eq!(c.dequant_rows, 0, "f32 never dequantizes");
    }

    #[test]
    fn int8_pooled_scores_close_and_fused() {
        let mut r = Rng::new(43);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, cq) = paired_caches(n_kv, d, len, 44);
        let mut c = CostTracker::default();
        let pf = decode_pooled_scores(&q, &cf, g, &mut c);
        let mut c8 = CostTracker::default();
        let pq = decode_pooled_scores(&q, &cq, g, &mut c8);
        assert_eq!(c8.dequant_rows, 0, "scoring is fused over int8 — no dequant");
        for (a, b) in pf.iter().zip(&pq) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 5e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn int8_kv_bytes_shrink() {
        let (cf, cq) = paired_caches(2, 16, 200, 45);
        let (bf, bq) = (cf.kv_bytes(), cq.kv_bytes());
        let ratio = bf as f64 / bq as f64;
        assert!(ratio >= 1.8, "bytes ratio {ratio:.2} (f32 {bf} int8 {bq})");
    }

    #[test]
    fn int8_staged_tail_is_exact_f32() {
        // positions past the last full tile are staged — identical reads
        let (cf, cq) = paired_caches(2, 8, 41, 46); // 2 full tiles + 9 staged
        for h in 0..2 {
            for p in 32..41 {
                assert_eq!(cf.key(h, p), cq.key(h, p));
                assert_eq!(cf.val(h, p), cq.val(h, p));
                assert!(cq.quantized_key_row(h, p).is_none());
            }
            assert!(cq.quantized_key_row(h, 31).is_some());
        }
    }

    #[test]
    fn int8_truncate_mid_tile_restores_staging() {
        // truncate into a completed tile, then refill: reads must match a
        // cache that was never truncated past that point (up to the one
        // dequant/requant round-trip, which is deterministic)
        let (_, mut cq) = paired_caches(2, 8, 48, 47); // 3 full tiles
        let probe_q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.31).sin()).collect();
        let before: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
        cq.truncate(23); // mid-tile boundary inside full tile 1
        assert_eq!(cq.len, 23);
        let after: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
        // full tile 0 untouched (bitwise); restored rows within quant error
        for (p, (a, b)) in before.iter().zip(&after).enumerate() {
            if p < 16 {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {p}");
            } else {
                assert!((a - b).abs() < 1e-3, "pos {p}: {a} vs {b}");
            }
        }
        // refilling re-quantizes the tail tile without panicking
        let k = vec![0.25; 2 * 8];
        for _ in 0..12 {
            cq.push(&k, &k);
        }
        assert_eq!(cq.len, 35);
        assert!(cq.quantized_key_row(0, 17).is_some());
    }

    #[test]
    fn truncate_matches_fresh_fill() {
        // truncating to n must leave the same state (incl. page summaries)
        // as pushing only the first n entries into a fresh cache
        let mut r = Rng::new(9);
        let (n_kv, d, len, n) = (2, 8, 40, 23); // 23 = mid-page for page_size 16
        let mut rows = Vec::new();
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            rows.push((k, v));
        }
        let mut full = KvCache::new(n_kv, d, len);
        let mut short = KvCache::new(n_kv, d, len);
        for (k, v) in &rows {
            full.push(k, v);
        }
        for (k, v) in rows.iter().take(n) {
            short.push(k, v);
        }
        full.truncate(n);
        assert_eq!(full.len, n);
        assert_eq!(full.n_pages(), short.n_pages());
        for h in 0..n_kv {
            for p in 0..n {
                assert_eq!(full.key(h, p), short.key(h, p));
                assert_eq!(full.val(h, p), short.val(h, p));
            }
            for page in 0..full.n_pages() {
                let (amin, amax) = full.page_summary(h, page);
                let (bmin, bmax) = short.page_summary(h, page);
                assert_eq!(amin, bmin, "page {page} min");
                assert_eq!(amax, bmax, "page {page} max");
            }
        }
    }
}
